//! Reverse-mode autodiff on a flat tape of tensor ops.
//!
//! The op set is exactly what the paper's decoder-only transformer needs
//! — matrix products (via the [`crate::linalg`] kernels), residual
//! add/sub, ReLU, LayerNorm, fused causal self-attention, embedding
//! gather, and fused softmax cross-entropy — nothing more. Every op
//! stores its forward value (plus the minimal aux state its backward
//! rule needs: softmax rows, LN row statistics), so one
//! [`Tape::backward`] pass yields gradients for every trainable leaf
//! and for the stage-boundary input, which is what the pipeline ships
//! upstream.
//!
//! Determinism (DESIGN.md §8/§13): every op is either a serial loop
//! with fixed iteration order, a delegate to the thread-count-bit-stable
//! linalg kernels, or — for the attention and cross-entropy hot spots —
//! data-parallel over the `par` pool with each output region owned by
//! exactly one task whose internal arithmetic is the serial loop
//! verbatim (batch rows for attention, row blocks for cross-entropy;
//! the scalar loss folds per-row f64 terms in row order on the caller).
//! A tape program therefore produces identical bits under any
//! `--threads` budget, which is what lets `exp convergence-native` keep
//! the byte-identical-CSV contract.
//!
//! Memory: [`Tape::bytes`] reports the bytes held by values, aux state,
//! and accumulated gradients — the number `memory.rs` checks against its
//! analytic native-backend model. [`Tape::backward_into`] keeps matmul
//! weight gradients *off* the tape entirely, streaming them into the
//! caller's cross-microbatch accumulators.

use crate::linalg;
use crate::tensor::{IntTensor, Tensor};

/// LayerNorm variance epsilon (matches python/compile/model.py).
pub const LN_EPS: f32 = 1e-5;

/// Handle to one tape node.
#[derive(Clone, Copy, Debug)]
pub struct Var {
    id: usize,
}

/// One differentiable operation (inputs are node ids, always < self).
enum Op {
    /// input or parameter tensor
    Leaf,
    /// C = A·B
    Matmul { a: usize, b: usize },
    /// C = A·Bᵀ (boundary reconstruction Xc·Uᵀ)
    MatmulNT { a: usize, b: usize },
    /// C = A + B
    Add { a: usize, b: usize },
    /// C = A − B (high-rank component subtraction before projection)
    Sub { a: usize, b: usize },
    /// C = max(A, 0)
    Relu { x: usize },
    /// row-wise layer norm with gain/bias; saves per-row (μ, 1/σ)
    LayerNorm { x: usize, g: usize, b: usize, mu: Vec<f32>, rstd: Vec<f32> },
    /// fused multi-head causal self-attention; saves softmax rows
    Attention { q: usize, k: usize, v: usize, dims: AttnDims, att: Vec<f32> },
    /// row gather C[i] = table[tok[i]]
    Embed { table: usize, tok: IntTensor },
    /// mean softmax cross-entropy over all rows; saves softmax probs
    CrossEntropy { logits: usize, targets: IntTensor, probs: Vec<f32> },
}

/// Static shape of a fused attention op.
#[derive(Clone, Copy, Debug)]
pub struct AttnDims {
    /// microbatch size
    pub b: usize,
    /// sequence length
    pub n: usize,
    /// attention heads
    pub heads: usize,
    /// embedding dim (heads · head_dim)
    pub d: usize,
}

struct Node {
    op: Op,
    value: Tensor,
    grad: Option<Tensor>,
    requires_grad: bool,
}

impl Node {
    fn aux_bytes(&self) -> usize {
        match &self.op {
            Op::LayerNorm { mu, rstd, .. } => (mu.len() + rstd.len()) * 4,
            Op::Attention { att, .. } => att.len() * 4,
            Op::CrossEntropy { probs, targets, .. } => {
                probs.len() * 4 + targets.numel() * 4
            }
            Op::Embed { tok, .. } => tok.numel() * 4,
            _ => 0,
        }
    }
}

/// A reverse-mode autodiff tape: build the graph forward, then call
/// [`Tape::backward`] once from the root.
pub struct Tape {
    nodes: Vec<Node>,
}

impl Default for Tape {
    fn default() -> Self {
        Tape::new()
    }
}

impl Tape {
    /// Empty tape.
    pub fn new() -> Tape {
        Tape { nodes: Vec::new() }
    }

    fn push(&mut self, op: Op, value: Tensor, requires_grad: bool) -> Var {
        let id = self.nodes.len();
        self.nodes.push(Node { op, value, grad: None, requires_grad });
        Var { id }
    }

    fn req(&self, v: Var) -> bool {
        self.nodes[v.id].requires_grad
    }

    /// Register an input tensor. `trainable` marks it as wanting a
    /// gradient (parameters, boundary inputs); constants (U, the
    /// high-rank E component) pass `false` and backward never touches
    /// them.
    pub fn leaf(&mut self, value: Tensor, trainable: bool) -> Var {
        self.push(Op::Leaf, value, trainable)
    }

    /// Forward value of a node.
    pub fn value(&self, v: Var) -> &Tensor {
        &self.nodes[v.id].value
    }

    /// Accumulated gradient of a node (after [`Tape::backward`]); `None`
    /// for constants and nodes the root does not depend on.
    pub fn grad(&self, v: Var) -> Option<&Tensor> {
        self.nodes[v.id].grad.as_ref()
    }

    /// Bytes held by node values, op aux state, and gradients — the
    /// measured quantity behind `memory::native_*` accounting.
    pub fn bytes(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| {
                n.value.numel() * 4
                    + n.aux_bytes()
                    + n.grad.as_ref().map_or(0, |g| g.numel() * 4)
            })
            .sum()
    }

    // ---- ops --------------------------------------------------------------

    /// C = A·B.
    pub fn matmul(&mut self, a: Var, b: Var) -> Var {
        let value = linalg::matmul(self.value(a), self.value(b));
        let rg = self.req(a) || self.req(b);
        self.push(Op::Matmul { a: a.id, b: b.id }, value, rg)
    }

    /// C = A·Bᵀ (never materializes Bᵀ).
    pub fn matmul_nt(&mut self, a: Var, b: Var) -> Var {
        let value = linalg::matmul_nt(self.value(a), self.value(b));
        let rg = self.req(a) || self.req(b);
        self.push(Op::MatmulNT { a: a.id, b: b.id }, value, rg)
    }

    /// C = A + B (same shape).
    pub fn add(&mut self, a: Var, b: Var) -> Var {
        let (ta, tb) = (self.value(a), self.value(b));
        debug_assert_eq!(ta.shape, tb.shape);
        let data = ta.data.iter().zip(&tb.data).map(|(x, y)| x + y).collect();
        let value = Tensor::new(ta.shape.clone(), data);
        let rg = self.req(a) || self.req(b);
        self.push(Op::Add { a: a.id, b: b.id }, value, rg)
    }

    /// C = A − B (same shape).
    pub fn sub(&mut self, a: Var, b: Var) -> Var {
        let (ta, tb) = (self.value(a), self.value(b));
        debug_assert_eq!(ta.shape, tb.shape);
        let data = ta.data.iter().zip(&tb.data).map(|(x, y)| x - y).collect();
        let value = Tensor::new(ta.shape.clone(), data);
        let rg = self.req(a) || self.req(b);
        self.push(Op::Sub { a: a.id, b: b.id }, value, rg)
    }

    /// C = max(A, 0).
    pub fn relu(&mut self, x: Var) -> Var {
        let t = self.value(x);
        let data = t.data.iter().map(|v| v.max(0.0)).collect();
        let value = Tensor::new(t.shape.clone(), data);
        let rg = self.req(x);
        self.push(Op::Relu { x: x.id }, value, rg)
    }

    /// Row-wise LayerNorm over the last dim of a 2-D input:
    /// `y = (x − μ)/√(σ² + ε) · g + b` with 1-D gain/bias.
    pub fn layer_norm(&mut self, x: Var, g: Var, b: Var) -> Var {
        let t = self.value(x);
        let (rows, d) = t.dims2();
        let gv = &self.value(g).data;
        let bv = &self.value(b).data;
        debug_assert_eq!(gv.len(), d);
        debug_assert_eq!(bv.len(), d);
        let mut out = vec![0.0f32; rows * d];
        let mut mu = vec![0.0f32; rows];
        let mut rstd = vec![0.0f32; rows];
        for r in 0..rows {
            let row = &t.data[r * d..(r + 1) * d];
            let mean = row.iter().map(|v| *v as f64).sum::<f64>() / d as f64;
            let var = row
                .iter()
                .map(|v| (*v as f64 - mean).powi(2))
                .sum::<f64>()
                / d as f64;
            let rs = 1.0 / (var + LN_EPS as f64).sqrt();
            mu[r] = mean as f32;
            rstd[r] = rs as f32;
            let orow = &mut out[r * d..(r + 1) * d];
            for j in 0..d {
                let xhat = (row[j] - mu[r]) * rstd[r];
                orow[j] = xhat * gv[j] + bv[j];
            }
        }
        let value = Tensor::new(vec![rows, d], out);
        let rg = self.req(x) || self.req(g) || self.req(b);
        self.push(
            Op::LayerNorm { x: x.id, g: g.id, b: b.id, mu, rstd },
            value,
            rg,
        )
    }

    /// Fused multi-head causal self-attention over (b·n, d) inputs
    /// already projected to Q/K/V: per (batch, head), softmax(QKᵀ/√d_h)
    /// with a causal mask, times V. Saves the softmax rows for backward.
    pub fn causal_attention(
        &mut self,
        q: Var,
        k: Var,
        v: Var,
        dims: AttnDims,
    ) -> Var {
        let AttnDims { b, n, heads, d } = dims;
        let dh = d / heads;
        debug_assert_eq!(dh * heads, d);
        debug_assert_eq!(self.value(q).shape, vec![b * n, d]);
        let (qd, kd, vd) =
            (&self.value(q).data, &self.value(k).data, &self.value(v).data);
        // batch rows are independent: run each on the par pool and
        // stitch the owned chunks back in batch order — per-chunk
        // arithmetic is the serial loop verbatim, so the result is
        // bitwise the same at any thread count
        let bis: Vec<usize> = (0..b).collect();
        let threads = crate::par::kernel_threads().min(b.max(1));
        let parts = crate::par::map(threads, &bis, |_, &bi| {
            attention_forward_batch(qd, kd, vd, dims, bi)
        });
        let mut att = Vec::with_capacity(b * heads * n * n);
        let mut out = Vec::with_capacity(b * n * d);
        for (a_chunk, o_chunk) in parts {
            att.extend_from_slice(&a_chunk);
            out.extend_from_slice(&o_chunk);
        }
        let value = Tensor::new(vec![b * n, d], out);
        let rg = self.req(q) || self.req(k) || self.req(v);
        self.push(
            Op::Attention { q: q.id, k: k.id, v: v.id, dims, att },
            value,
            rg,
        )
    }

    /// Row gather: C[i, :] = table[tok[i], :] for a (b, n) token tensor,
    /// producing (b·n, d).
    pub fn embed(&mut self, table: Var, tok: &IntTensor) -> Var {
        let t = self.value(table);
        let (vocab, d) = t.dims2();
        let rows = tok.numel();
        let mut out = vec![0.0f32; rows * d];
        for (i, &id) in tok.data.iter().enumerate() {
            let id = id as usize;
            debug_assert!(id < vocab);
            out[i * d..(i + 1) * d]
                .copy_from_slice(&t.data[id * d..(id + 1) * d]);
        }
        let value = Tensor::new(vec![rows, d], out);
        let rg = self.req(table);
        self.push(Op::Embed { table: table.id, tok: tok.clone() }, value, rg)
    }

    /// Fused softmax cross-entropy, averaged over every (row, target)
    /// pair: scalar `−mean log softmax(logits)[target]`.
    pub fn cross_entropy(&mut self, logits: Var, targets: &IntTensor) -> Var {
        let t = self.value(logits);
        let (rows, vocab) = t.dims2();
        debug_assert_eq!(targets.numel(), rows);
        // rows are independent: block them across the par pool; the
        // scalar loss folds the per-row f64 terms serially in row order
        // afterwards, so neither probs nor the loss bits depend on the
        // pool width or the block boundaries
        let threads = crate::par::kernel_threads().min(rows.max(1));
        let per = ((rows + threads - 1) / threads.max(1)).max(1);
        let blocks: Vec<(usize, usize)> = (0..rows)
            .step_by(per)
            .map(|r0| (r0, (r0 + per).min(rows)))
            .collect();
        let td = &t.data;
        let parts = crate::par::map(threads, &blocks, |_, &(r0, r1)| {
            let mut probs = vec![0.0f32; (r1 - r0) * vocab];
            let mut losses = vec![0.0f64; r1 - r0];
            for r in r0..r1 {
                let row = &td[r * vocab..(r + 1) * vocab];
                let mx =
                    row.iter().fold(f32::NEG_INFINITY, |m, x| m.max(*x));
                let mut sum = 0.0f64;
                let prow =
                    &mut probs[(r - r0) * vocab..(r - r0 + 1) * vocab];
                for (p, l) in prow.iter_mut().zip(row) {
                    let e = (l - mx).exp();
                    *p = e;
                    sum += e as f64;
                }
                let inv = (1.0 / sum) as f32;
                for p in prow.iter_mut() {
                    *p *= inv;
                }
                let tgt = targets.data[r] as usize;
                debug_assert!(tgt < vocab);
                losses[r - r0] = -((row[tgt] - mx) as f64 - sum.ln());
            }
            (probs, losses)
        });
        let mut probs = Vec::with_capacity(rows * vocab);
        let mut loss = 0.0f64;
        for (p_chunk, l_chunk) in parts {
            probs.extend_from_slice(&p_chunk);
            for l in l_chunk {
                // `loss += -x` is bit-identical to the serial `loss -= x`
                loss += l;
            }
        }
        let value = Tensor::scalar((loss / rows as f64) as f32);
        let rg = self.req(logits);
        self.push(
            Op::CrossEntropy {
                logits: logits.id,
                targets: targets.clone(),
                probs,
            },
            value,
            rg,
        )
    }

    // ---- backward ---------------------------------------------------------

    /// Reverse pass from a scalar root (seeds d root = 1).
    pub fn backward(&mut self, root: Var) {
        let seed = Tensor::scalar(1.0);
        self.backward_from(root, seed);
    }

    /// Reverse pass from any root with an explicit output cotangent —
    /// how non-last stages inject the boundary gradient arriving from
    /// downstream.
    pub fn backward_from(&mut self, root: Var, seed: Tensor) {
        self.reverse(root, seed, None);
    }

    /// Reverse pass that streams matmul weight gradients straight into
    /// caller-owned accumulators instead of materializing them on the
    /// tape: for every `Op::Matmul`/`Op::MatmulNT` whose weight side is
    /// a leaf listed in `params`, the dW product runs as
    /// [`linalg::matmul_tn_acc`] into `acc[i]` (the microbatch-fused
    /// accumulation). Called once per microbatch in microbatch order,
    /// the accumulated dW is **bitwise** what one `matmul_tn` over the
    /// row-concatenated microbatch activations would produce — the
    /// kernel streams the shared index ascending — so fused and
    /// concatenated-unfused gradients are exactly equal at any thread
    /// count. Non-matmul parameters (LayerNorm gain/bias, the embedding
    /// table) keep their tape gradients; harvest those with the usual
    /// per-param `grad()` walk, which sees `None` for fused weights and
    /// therefore never double-counts.
    ///
    /// `seed` is the output cotangent (`None` seeds a scalar 1 — the
    /// last-stage loss root).
    pub fn backward_into(
        &mut self,
        root: Var,
        seed: Option<Tensor>,
        params: &[Var],
        acc: &mut [Tensor],
    ) {
        debug_assert_eq!(params.len(), acc.len());
        let seed = seed.unwrap_or_else(|| Tensor::scalar(1.0));
        let mut slots = vec![None; self.nodes.len()];
        for (i, p) in params.iter().enumerate() {
            if matches!(self.nodes[p.id].op, Op::Leaf) {
                slots[p.id] = Some(i);
            }
        }
        self.reverse(root, seed, Some((slots, acc)));
    }

    /// Shared reverse walk behind [`Tape::backward_from`] and
    /// [`Tape::backward_into`]; `fused` maps node id → fused
    /// accumulator index for the weight-gradient fast path.
    fn reverse(
        &mut self,
        root: Var,
        seed: Tensor,
        mut fused: Option<(Vec<Option<usize>>, &mut [Tensor])>,
    ) {
        debug_assert_eq!(self.nodes[root.id].value.shape, seed.shape);
        if !self.nodes[root.id].requires_grad {
            return;
        }
        self.nodes[root.id].grad = Some(seed);
        for id in (0..=root.id).rev() {
            let (head, tail) = self.nodes.split_at_mut(id);
            let node = &mut tail[0];
            if node.grad.is_none() || !node.requires_grad {
                continue;
            }
            let g = node.grad.as_ref().unwrap();
            match &node.op {
                Op::Leaf => {}
                Op::Matmul { a, b } => {
                    if head[*a].requires_grad {
                        let da = linalg::matmul_nt(g, &head[*b].value);
                        accumulate(&mut head[*a], da);
                    }
                    if head[*b].requires_grad {
                        let slot =
                            fused.as_ref().and_then(|(s, _)| s[*b]);
                        match (slot, fused.as_mut()) {
                            (Some(ai), Some((_, acc))) => {
                                // fused path: dW = Aᵀ·g streamed into
                                // the cross-microbatch accumulator
                                linalg::matmul_tn_acc(
                                    &head[*a].value,
                                    g,
                                    &mut acc[ai],
                                );
                            }
                            _ => {
                                let db = linalg::matmul_tn(
                                    &head[*a].value,
                                    g,
                                );
                                accumulate(&mut head[*b], db);
                            }
                        }
                    }
                }
                Op::MatmulNT { a, b } => {
                    if head[*a].requires_grad {
                        let da = linalg::matmul(g, &head[*b].value);
                        accumulate(&mut head[*a], da);
                    }
                    if head[*b].requires_grad {
                        let slot =
                            fused.as_ref().and_then(|(s, _)| s[*b]);
                        match (slot, fused.as_mut()) {
                            (Some(ai), Some((_, acc))) => {
                                linalg::matmul_tn_acc(
                                    g,
                                    &head[*a].value,
                                    &mut acc[ai],
                                );
                            }
                            _ => {
                                let db = linalg::matmul_tn(
                                    g,
                                    &head[*a].value,
                                );
                                accumulate(&mut head[*b], db);
                            }
                        }
                    }
                }
                Op::Add { a, b } => {
                    let (a, b) = (*a, *b);
                    let g = g.clone();
                    if head[a].requires_grad {
                        accumulate(&mut head[a], g.clone());
                    }
                    if head[b].requires_grad {
                        accumulate(&mut head[b], g);
                    }
                }
                Op::Sub { a, b } => {
                    let (a, b) = (*a, *b);
                    if head[a].requires_grad {
                        accumulate(&mut head[a], g.clone());
                    }
                    if head[b].requires_grad {
                        let mut ng = g.clone();
                        ng.scale(-1.0);
                        accumulate(&mut head[b], ng);
                    }
                }
                Op::Relu { x } => {
                    let xv = &head[*x].value;
                    let data = xv
                        .data
                        .iter()
                        .zip(&g.data)
                        .map(|(x, gv)| if *x > 0.0 { *gv } else { 0.0 })
                        .collect();
                    let dx = Tensor::new(xv.shape.clone(), data);
                    accumulate(&mut head[*x], dx);
                }
                Op::LayerNorm { x, g: gp, b: bp, mu, rstd } => {
                    let (dx, dg, db) = layer_norm_backward(
                        &head[*x].value,
                        &head[*gp].value,
                        mu,
                        rstd,
                        g,
                    );
                    let (x, gp, bp) = (*x, *gp, *bp);
                    if head[x].requires_grad {
                        accumulate(&mut head[x], dx);
                    }
                    if head[gp].requires_grad {
                        accumulate(&mut head[gp], dg);
                    }
                    if head[bp].requires_grad {
                        accumulate(&mut head[bp], db);
                    }
                }
                Op::Attention { q, k, v, dims, att } => {
                    let (dq, dk, dv) = attention_backward(
                        &head[*q].value,
                        &head[*k].value,
                        &head[*v].value,
                        *dims,
                        att,
                        g,
                    );
                    let (q, k, v) = (*q, *k, *v);
                    if head[q].requires_grad {
                        accumulate(&mut head[q], dq);
                    }
                    if head[k].requires_grad {
                        accumulate(&mut head[k], dk);
                    }
                    if head[v].requires_grad {
                        accumulate(&mut head[v], dv);
                    }
                }
                Op::Embed { table, tok } => {
                    let tv = &head[*table].value;
                    let (_, d) = tv.dims2();
                    let mut dt = Tensor::zeros(&tv.shape);
                    for (i, &id) in tok.data.iter().enumerate() {
                        let id = id as usize;
                        let src = &g.data[i * d..(i + 1) * d];
                        let dst = &mut dt.data[id * d..(id + 1) * d];
                        for (dv, sv) in dst.iter_mut().zip(src) {
                            *dv += sv;
                        }
                    }
                    accumulate(&mut head[*table], dt);
                }
                Op::CrossEntropy { logits, targets, probs } => {
                    let lv = &head[*logits].value;
                    let (rows, vocab) = lv.dims2();
                    let scale = g.item() / rows as f32;
                    let mut dl = vec![0.0f32; rows * vocab];
                    for r in 0..rows {
                        let prow = &probs[r * vocab..(r + 1) * vocab];
                        let drow = &mut dl[r * vocab..(r + 1) * vocab];
                        for (d, p) in drow.iter_mut().zip(prow) {
                            *d = p * scale;
                        }
                        drow[targets.data[r] as usize] -= scale;
                    }
                    let dl = Tensor::new(vec![rows, vocab], dl);
                    accumulate(&mut head[*logits], dl);
                }
            }
        }
    }
}

fn accumulate(node: &mut Node, delta: Tensor) {
    match &mut node.grad {
        Some(g) => g.add_assign(&delta),
        None => node.grad = Some(delta),
    }
}

/// LayerNorm backward: returns (dx, dg, db).
fn layer_norm_backward(
    x: &Tensor,
    g: &Tensor,
    mu: &[f32],
    rstd: &[f32],
    dy: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (rows, d) = x.dims2();
    let mut dx = vec![0.0f32; rows * d];
    let mut dg = vec![0.0f64; d];
    let mut db = vec![0.0f64; d];
    for r in 0..rows {
        let xrow = &x.data[r * d..(r + 1) * d];
        let dyrow = &dy.data[r * d..(r + 1) * d];
        let dxrow = &mut dx[r * d..(r + 1) * d];
        let (m, rs) = (mu[r], rstd[r]);
        // dŷ = dy·g; means of dŷ and dŷ·x̂ over the row
        let mut m1 = 0.0f64;
        let mut m2 = 0.0f64;
        for j in 0..d {
            let xhat = (xrow[j] - m) * rs;
            let dyh = (dyrow[j] * g.data[j]) as f64;
            m1 += dyh;
            m2 += dyh * xhat as f64;
            dg[j] += (dyrow[j] * xhat) as f64;
            db[j] += dyrow[j] as f64;
        }
        m1 /= d as f64;
        m2 /= d as f64;
        for j in 0..d {
            let xhat = (xrow[j] - m) * rs;
            let dyh = (dyrow[j] * g.data[j]) as f64;
            dxrow[j] =
                (rs as f64 * (dyh - m1 - xhat as f64 * m2)) as f32;
        }
    }
    (
        Tensor::new(vec![rows, d], dx),
        Tensor::new(vec![d], dg.into_iter().map(|v| v as f32).collect()),
        Tensor::new(vec![d], db.into_iter().map(|v| v as f32).collect()),
    )
}

/// Forward fused causal attention for ONE batch row `bi`: returns the
/// (heads·n·n) softmax chunk and the (n·d) output chunk that row owns.
/// This is the serial per-batch loop body, factored out so the op can
/// fan batch rows across the `par` pool without changing any bit.
fn attention_forward_batch(
    qd: &[f32],
    kd: &[f32],
    vd: &[f32],
    dims: AttnDims,
    bi: usize,
) -> (Vec<f32>, Vec<f32>) {
    let AttnDims { b: _, n, heads, d } = dims;
    let dh = d / heads;
    let scale = 1.0f32 / (dh as f32).sqrt();
    let mut att = vec![0.0f32; heads * n * n];
    let mut out = vec![0.0f32; n * d];
    for h in 0..heads {
        let off = h * dh;
        for i in 0..n {
            let qrow = &qd[(bi * n + i) * d + off..][..dh];
            let arow = &mut att[(h * n + i) * n..][..n];
            // causal scores for j ≤ i
            let mut mx = f32::NEG_INFINITY;
            for (j, aj) in arow.iter_mut().enumerate().take(i + 1) {
                let krow = &kd[(bi * n + j) * d + off..][..dh];
                let mut s = 0.0f32;
                for (qc, kc) in qrow.iter().zip(krow) {
                    s += qc * kc;
                }
                let s = s * scale;
                *aj = s;
                mx = mx.max(s);
            }
            // softmax over the unmasked prefix
            let mut sum = 0.0f64;
            for aj in arow.iter_mut().take(i + 1) {
                let e = (*aj - mx).exp();
                *aj = e;
                sum += e as f64;
            }
            let inv = (1.0 / sum) as f32;
            for aj in arow.iter_mut().take(i + 1) {
                *aj *= inv;
            }
            // out_i = Σ_j att_ij · v_j
            let orow = &mut out[i * d + off..][..dh];
            for j in 0..=i {
                let a = arow[j];
                let vrow = &vd[(bi * n + j) * d + off..][..dh];
                for (oc, vc) in orow.iter_mut().zip(vrow) {
                    *oc += a * vc;
                }
            }
        }
    }
    (att, out)
}

/// Fused causal-attention backward: returns (dQ, dK, dV). Batch rows
/// are independent (the causal mask never crosses a batch row), so they
/// fan across the `par` pool exactly like the forward pass — each task
/// owns the dQ/dK/dV chunks of one batch row and runs the serial loop
/// verbatim, keeping the result bitwise thread-count-invariant.
fn attention_backward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    dims: AttnDims,
    att: &[f32],
    dout: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let AttnDims { b, n, heads, d } = dims;
    let bis: Vec<usize> = (0..b).collect();
    let threads = crate::par::kernel_threads().min(b.max(1));
    let parts = crate::par::map(threads, &bis, |_, &bi| {
        attention_backward_batch(q, k, v, dims, att, dout, bi)
    });
    let mut dq = Vec::with_capacity(b * n * d);
    let mut dk = Vec::with_capacity(b * n * d);
    let mut dv = Vec::with_capacity(b * n * d);
    for (dq_chunk, dk_chunk, dv_chunk) in parts {
        dq.extend_from_slice(&dq_chunk);
        dk.extend_from_slice(&dk_chunk);
        dv.extend_from_slice(&dv_chunk);
    }
    (
        Tensor::new(vec![b * n, d], dq),
        Tensor::new(vec![b * n, d], dk),
        Tensor::new(vec![b * n, d], dv),
    )
}

/// Backward fused causal attention for ONE batch row: the (n·d) dQ, dK
/// and dV chunks that row owns.
fn attention_backward_batch(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    dims: AttnDims,
    att: &[f32],
    dout: &Tensor,
    bi: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let AttnDims { b: _, n, heads, d } = dims;
    let dh = d / heads;
    let scale = 1.0f32 / (dh as f32).sqrt();
    let mut dq = vec![0.0f32; n * d];
    let mut dk = vec![0.0f32; n * d];
    let mut dv = vec![0.0f32; n * d];
    let mut datt = vec![0.0f32; n];
    for h in 0..heads {
        let off = h * dh;
        for i in 0..n {
            let arow = &att[((bi * heads + h) * n + i) * n..][..n];
            let dorow = &dout.data[(bi * n + i) * d + off..][..dh];
            // dV_j += att_ij · dOut_i;  dAtt_ij = dOut_i · V_j
            for j in 0..=i {
                let a = arow[j];
                let vrow = &v.data[(bi * n + j) * d + off..][..dh];
                let dvrow = &mut dv[j * d + off..][..dh];
                let mut dot = 0.0f32;
                for c in 0..dh {
                    dvrow[c] += a * dorow[c];
                    dot += dorow[c] * vrow[c];
                }
                datt[j] = dot;
            }
            // softmax backward on the causal prefix:
            // dS_ij = att_ij (dAtt_ij − Σ_l att_il dAtt_il)
            let mut inner = 0.0f64;
            for j in 0..=i {
                inner += (arow[j] * datt[j]) as f64;
            }
            let inner = inner as f32;
            let qrow = &q.data[(bi * n + i) * d + off..][..dh];
            let dqrow_i = &mut dq[i * d + off..][..dh];
            for j in 0..=i {
                let ds = arow[j] * (datt[j] - inner) * scale;
                let krow = &k.data[(bi * n + j) * d + off..][..dh];
                for (dqc, kc) in dqrow_i.iter_mut().zip(krow) {
                    *dqc += ds * kc;
                }
                let dkrow = &mut dk[j * d + off..][..dh];
                for (dkc, qc) in dkrow.iter_mut().zip(qrow) {
                    *dkc += ds * qc;
                }
            }
        }
    }
    (dq, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randt(rng: &mut Rng, shape: &[usize]) -> Tensor {
        Tensor::new(
            shape.to_vec(),
            rng.normal_f32_vec(shape.iter().product(), 1.0),
        )
    }

    #[test]
    fn matmul_grads_match_hand_computed() {
        // L = Σ (A·B): dA = 1·Bᵀ row-sums, dB = Aᵀ·1
        let mut tape = Tape::new();
        let a = tape.leaf(
            Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
            true,
        );
        let b = tape.leaf(
            Tensor::new(vec![2, 2], vec![5.0, 6.0, 7.0, 8.0]),
            true,
        );
        let c = tape.matmul(a, b);
        tape.backward_from(c, Tensor::new(vec![2, 2], vec![1.0; 4]));
        assert_eq!(tape.grad(a).unwrap().data, vec![11.0, 15.0, 11.0, 15.0]);
        assert_eq!(tape.grad(b).unwrap().data, vec![4.0, 4.0, 6.0, 6.0]);
    }

    #[test]
    fn matmul_nt_consistent_with_matmul_of_transpose() {
        let mut rng = Rng::new(2);
        let av = randt(&mut rng, &[3, 5]);
        let bv = randt(&mut rng, &[4, 5]);
        let seed = randt(&mut rng, &[3, 4]);

        let mut t1 = Tape::new();
        let a1 = t1.leaf(av.clone(), true);
        let b1 = t1.leaf(bv.clone(), true);
        let c1 = t1.matmul_nt(a1, b1);
        t1.backward_from(c1, seed.clone());

        let mut t2 = Tape::new();
        let a2 = t2.leaf(av, true);
        let b2 = t2.leaf(linalg::transpose(&bv), true);
        let c2 = t2.matmul(a2, b2);
        t2.backward_from(c2, seed);

        assert_eq!(t1.value(c1).data, t2.value(c2).data);
        for (x, y) in t1
            .grad(a1)
            .unwrap()
            .data
            .iter()
            .zip(&t2.grad(a2).unwrap().data)
        {
            assert!((x - y).abs() < 1e-5);
        }
        let g2t = linalg::transpose(t2.grad(b2).unwrap());
        for (x, y) in t1.grad(b1).unwrap().data.iter().zip(&g2t.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn constants_get_no_grad_and_fanout_accumulates() {
        let mut rng = Rng::new(3);
        let mut tape = Tape::new();
        let x = tape.leaf(randt(&mut rng, &[4, 4]), true);
        let c = tape.leaf(randt(&mut rng, &[4, 4]), false);
        let s = tape.add(x, c);
        let y = tape.add(s, x); // x used twice: grads must accumulate
        tape.backward_from(y, Tensor::new(vec![4, 4], vec![1.0; 16]));
        assert!(tape.grad(c).is_none());
        assert!(tape.grad(x).unwrap().data.iter().all(|g| *g == 2.0));
    }

    #[test]
    fn relu_masks_gradient() {
        let mut tape = Tape::new();
        let x = tape.leaf(
            Tensor::new(vec![1, 4], vec![-1.0, 0.0, 0.5, 2.0]),
            true,
        );
        let y = tape.relu(x);
        tape.backward_from(y, Tensor::new(vec![1, 4], vec![1.0; 4]));
        assert_eq!(tape.grad(x).unwrap().data, vec![0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn layer_norm_output_is_normalized() {
        let mut rng = Rng::new(4);
        let mut tape = Tape::new();
        let x = tape.leaf(randt(&mut rng, &[6, 32]), true);
        let g = tape.leaf(Tensor::new(vec![32], vec![1.0; 32]), true);
        let b = tape.leaf(Tensor::zeros(&[32]), true);
        let y = tape.layer_norm(x, g, b);
        let yv = tape.value(y);
        for r in 0..6 {
            let row = &yv.data[r * 32..(r + 1) * 32];
            let mean: f64 =
                row.iter().map(|v| *v as f64).sum::<f64>() / 32.0;
            let var: f64 = row
                .iter()
                .map(|v| (*v as f64 - mean).powi(2))
                .sum::<f64>()
                / 32.0;
            assert!(mean.abs() < 1e-5, "row {r} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
        }
        // db is the column-sum of dy
        let seed = randt(&mut rng, &[6, 32]);
        let mut colsum = vec![0.0f32; 32];
        for r in 0..6 {
            for j in 0..32 {
                colsum[j] += seed.data[r * 32 + j];
            }
        }
        tape.backward_from(y, seed);
        for (x, y) in tape.grad(b).unwrap().data.iter().zip(&colsum) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn attention_is_causal() {
        // output at position i must not depend on inputs at j > i:
        // perturb the last token's q/k/v and check earlier outputs fixed
        let mut rng = Rng::new(5);
        let dims = AttnDims { b: 2, n: 8, heads: 2, d: 16 };
        let (qv, kv, vv) = (
            randt(&mut rng, &[16, 16]),
            randt(&mut rng, &[16, 16]),
            randt(&mut rng, &[16, 16]),
        );
        let out = |qv: &Tensor, kv: &Tensor, vv: &Tensor| {
            let mut tape = Tape::new();
            let q = tape.leaf(qv.clone(), false);
            let k = tape.leaf(kv.clone(), false);
            let v = tape.leaf(vv.clone(), false);
            let o = tape.causal_attention(q, k, v, dims);
            tape.value(o).clone()
        };
        let base = out(&qv, &kv, &vv);
        let mut kv2 = kv.clone();
        for c in 0..16 {
            kv2.data[7 * 16 + c] += 1.0; // last token of batch 0
        }
        let pert = out(&qv, &kv2, &vv);
        for i in 0..7 {
            for c in 0..16 {
                assert_eq!(
                    base.data[i * 16 + c],
                    pert.data[i * 16 + c],
                    "pos {i} changed"
                );
            }
        }
        // attention rows sum to 1 over the causal prefix: uniform V maps
        // to itself
        let ones = Tensor::new(vec![16, 16], vec![1.0; 256]);
        let o = out(&qv, &kv, &ones);
        for x in &o.data {
            assert!((x - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn cross_entropy_of_uniform_logits_is_log_vocab() {
        let mut tape = Tape::new();
        let logits = tape.leaf(Tensor::zeros(&[3, 8]), true);
        let targets = IntTensor::new(vec![3], vec![1, 5, 7]);
        let loss = tape.cross_entropy(logits, &targets);
        assert!((tape.value(loss).item() - (8.0f32).ln()).abs() < 1e-6);
        tape.backward(loss);
        let g = tape.grad(logits).unwrap();
        // rows sum to zero; target entry negative
        for r in 0..3 {
            let row = &g.data[r * 8..(r + 1) * 8];
            let sum: f32 = row.iter().sum();
            assert!(sum.abs() < 1e-6);
            assert!(row[targets.data[r] as usize] < 0.0);
        }
    }

    #[test]
    fn embed_scatters_gradient_by_token() {
        let mut tape = Tape::new();
        let table = tape.leaf(
            Tensor::new(vec![4, 2], (0..8).map(|x| x as f32).collect()),
            true,
        );
        let tok = IntTensor::new(vec![1, 3], vec![2, 0, 2]);
        let e = tape.embed(table, &tok);
        assert_eq!(tape.value(e).data, vec![4.0, 5.0, 0.0, 1.0, 4.0, 5.0]);
        tape.backward_from(e, Tensor::new(vec![3, 2], vec![1.0; 6]));
        let g = tape.grad(table).unwrap();
        assert_eq!(g.data, vec![1.0, 1.0, 0.0, 0.0, 2.0, 2.0, 0.0, 0.0]);
    }

    /// Build a graph exercising EVERY op kind (embed, layer_norm,
    /// matmul, causal_attention, matmul_nt, relu, add, sub,
    /// cross_entropy), run backward, and return the loss bits plus
    /// every trainable leaf's gradient.
    fn full_graph_grads() -> (u32, Vec<Vec<f32>>) {
        let mut rng = Rng::new(11);
        let dims = AttnDims { b: 4, n: 16, heads: 2, d: 32 };
        let (b, n, d, vocab) = (4usize, 16usize, 32usize, 40usize);
        let rows = b * n;
        let mut tape = Tape::new();
        let table = tape.leaf(randt(&mut rng, &[vocab, d]), true);
        let tok = IntTensor::new(
            vec![b, n],
            (0..rows).map(|i| ((i * 7 + 3) % vocab) as i32).collect(),
        );
        let x = tape.embed(table, &tok);
        let lg = tape.leaf(randt(&mut rng, &[d]), true);
        let lb = tape.leaf(randt(&mut rng, &[d]), true);
        let ln = tape.layer_norm(x, lg, lb);
        let wq = tape.leaf(randt(&mut rng, &[d, d]), true);
        let wk = tape.leaf(randt(&mut rng, &[d, d]), true);
        let wv = tape.leaf(randt(&mut rng, &[d, d]), true);
        let q = tape.matmul(ln, wq);
        let k = tape.matmul(ln, wk);
        let v = tape.matmul(ln, wv);
        let attn = tape.causal_attention(q, k, v, dims);
        let u = tape.leaf(randt(&mut rng, &[d, d]), true);
        let rec = tape.matmul_nt(attn, u);
        let r = tape.relu(rec);
        let s = tape.add(r, x);
        let e = tape.leaf(randt(&mut rng, &[rows, d]), false);
        let s2 = tape.sub(s, e);
        let wo = tape.leaf(randt(&mut rng, &[d, vocab]), true);
        let logits = tape.matmul(s2, wo);
        let targets = IntTensor::new(
            vec![rows],
            (0..rows).map(|i| ((i * 11 + 5) % vocab) as i32).collect(),
        );
        let loss = tape.cross_entropy(logits, &targets);
        tape.backward(loss);
        let grads = [table, lg, lb, wq, wk, wv, u, wo]
            .iter()
            .map(|p| tape.grad(*p).expect("trainable grad").data.clone())
            .collect();
        (tape.value(loss).item().to_bits(), grads)
    }

    #[test]
    fn backward_bitwise_stable_across_thread_counts() {
        // the §13 contract, end to end: loss AND every leaf gradient of
        // a graph touching every op kind are bit-identical at any
        // kernel-thread budget
        let _guard = crate::par::TEST_THREADS_LOCK.lock().unwrap();
        let before = crate::par::max_threads_setting();
        crate::par::set_max_threads(1);
        let (loss1, grads1) = full_graph_grads();
        for threads in [2usize, 4, 8] {
            crate::par::set_max_threads(threads);
            let (lossn, gradsn) = full_graph_grads();
            assert_eq!(loss1, lossn, "loss bits at threads={threads}");
            for (i, (a, b)) in grads1.iter().zip(&gradsn).enumerate() {
                assert_eq!(a, b, "grad {i} at threads={threads}");
            }
        }
        crate::par::set_max_threads(before);
    }

    #[test]
    fn backward_matmul_grads_match_reference_composition() {
        // the matmul_reference property extended to the backward path:
        // tape gradients of C = A·B and C = A·Bᵀ equal the reference-
        // matmul compositions dA = g·Bᵀ, dB = Aᵀ·g — to the bit (all
        // kernels keep the naive ascending accumulation order)
        let mut rng = Rng::new(12);
        let (m, k, n) = (21usize, 33usize, 18usize);
        let av = randt(&mut rng, &[m, k]);
        let bv = randt(&mut rng, &[k, n]);
        let seed = randt(&mut rng, &[m, n]);
        let mut tape = Tape::new();
        let a = tape.leaf(av.clone(), true);
        let b = tape.leaf(bv.clone(), true);
        let c = tape.matmul(a, b);
        tape.backward_from(c, seed.clone());
        let da_ref =
            linalg::matmul_reference(&seed, &linalg::transpose(&bv));
        let db_ref =
            linalg::matmul_reference(&linalg::transpose(&av), &seed);
        assert_eq!(tape.grad(a).unwrap().data, da_ref.data);
        assert_eq!(tape.grad(b).unwrap().data, db_ref.data);

        // and the NT variant: C = A·Uᵀ → dA = g·U, dU = gᵀ·A
        let uv = randt(&mut rng, &[n, k]);
        let seed2 = randt(&mut rng, &[m, n]);
        let mut t2 = Tape::new();
        let a2 = t2.leaf(av.clone(), true);
        let u2 = t2.leaf(uv.clone(), true);
        let c2 = t2.matmul_nt(a2, u2);
        t2.backward_from(c2, seed2.clone());
        let da2_ref = linalg::matmul_reference(&seed2, &uv);
        let du2_ref =
            linalg::matmul_reference(&linalg::transpose(&seed2), &av);
        assert_eq!(t2.grad(a2).unwrap().data, da2_ref.data);
        assert_eq!(t2.grad(u2).unwrap().data, du2_ref.data);
    }

    #[test]
    fn backward_into_fused_grads_match_concatenated_bitwise() {
        // the microbatch-fusion contract: backward_into per microbatch,
        // in microbatch order, accumulates weight grads EXACTLY as one
        // backward over the row-concatenated batch would — and the
        // fused weights leave no gradient on the tape
        let mut rng = Rng::new(13);
        let (k, n, p) = (24usize, 20usize, 16usize);
        let wv = randt(&mut rng, &[k, n]);
        let uv = randt(&mut rng, &[p, n]);
        let mbs: Vec<(Tensor, Tensor)> = [7usize, 12, 5]
            .iter()
            .map(|m| {
                (randt(&mut rng, &[*m, k]), randt(&mut rng, &[*m, p]))
            })
            .collect();

        // fused: per-microbatch backward_into on shared accumulators
        let mut acc =
            vec![Tensor::zeros(&[k, n]), Tensor::zeros(&[p, n])];
        for (xv, seed) in &mbs {
            let mut tape = Tape::new();
            let x = tape.leaf(xv.clone(), true);
            let w = tape.leaf(wv.clone(), true);
            let u = tape.leaf(uv.clone(), true);
            let y = tape.matmul(x, w);
            let z = tape.matmul_nt(y, u);
            tape.backward_into(
                z,
                Some(seed.clone()),
                &[w, u],
                &mut acc,
            );
            assert!(
                tape.grad(w).is_none() && tape.grad(u).is_none(),
                "fused weights must leave no tape gradient"
            );
            assert!(
                tape.grad(x).is_some(),
                "non-fused leaves keep tape gradients"
            );
        }

        // reference: ONE backward over the row-concatenated microbatches
        let cat = |sel: fn(&(Tensor, Tensor)) -> &Tensor, cols: usize| {
            let mut data = Vec::new();
            for mb in &mbs {
                data.extend_from_slice(&sel(mb).data);
            }
            Tensor::new(vec![data.len() / cols, cols], data)
        };
        let x_cat = cat(|mb| &mb.0, k);
        let seed_cat = cat(|mb| &mb.1, p);
        let mut tape = Tape::new();
        let x = tape.leaf(x_cat, true);
        let w = tape.leaf(wv.clone(), true);
        let u = tape.leaf(uv.clone(), true);
        let y = tape.matmul(x, w);
        let z = tape.matmul_nt(y, u);
        tape.backward_from(z, seed_cat);
        assert_eq!(acc[0].data, tape.grad(w).unwrap().data);
        assert_eq!(acc[1].data, tape.grad(u).unwrap().data);

        // the unfused M-small-matmuls-plus-adds path agrees within
        // rounding (association differs, so only approximately)
        let mut unfused =
            vec![Tensor::zeros(&[k, n]), Tensor::zeros(&[p, n])];
        for (xv, seed) in &mbs {
            let mut t = Tape::new();
            let x = t.leaf(xv.clone(), true);
            let w = t.leaf(wv.clone(), true);
            let u = t.leaf(uv.clone(), true);
            let y = t.matmul(x, w);
            let z = t.matmul_nt(y, u);
            t.backward_from(z, seed.clone());
            unfused[0].add_assign(t.grad(w).unwrap());
            unfused[1].add_assign(t.grad(u).unwrap());
        }
        for (f, uf) in acc.iter().zip(&unfused) {
            for (a, b) in f.data.iter().zip(&uf.data) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()));
            }
        }
    }

    #[test]
    fn bytes_accounting_grows_with_graph_and_backward() {
        let mut rng = Rng::new(6);
        let mut tape = Tape::new();
        let x = tape.leaf(randt(&mut rng, &[8, 16]), true);
        let b0 = tape.bytes();
        assert_eq!(b0, 8 * 16 * 4);
        let w = tape.leaf(randt(&mut rng, &[16, 16]), true);
        let y = tape.matmul(x, w);
        let fwd = tape.bytes();
        assert_eq!(fwd, b0 + 16 * 16 * 4 + 8 * 16 * 4);
        tape.backward_from(y, Tensor::new(vec![8, 16], vec![1.0; 128]));
        assert!(tape.bytes() > fwd, "grads must be counted");
    }
}
