//! Minimal CLI argument parser (the offline vendor set has no clap).
//!
//! Grammar: `protomodels <subcommand> [--flag value | --switch] …`

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command-line flags: `--key value` pairs, bare `--switch`es, and
/// positional arguments.
#[derive(Debug, Default)]
pub struct Flags {
    vals: BTreeMap<String, String>,
    switches: Vec<String>,
    /// non-flag arguments, in order
    pub positional: Vec<String>,
}

impl Flags {
    /// Parse raw arguments (excluding the program/subcommand name).
    pub fn parse(args: &[String]) -> Result<Flags> {
        let mut f = Flags::default();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(name) = a.strip_prefix("--") {
                // `--key value` unless next token is another flag / absent
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    f.vals.insert(name.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    f.switches.push(name.to_string());
                    i += 1;
                }
            } else {
                f.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(f)
    }

    /// String value of `--key`, or `default` when absent.
    pub fn str(&self, key: &str, default: &str) -> String {
        self.vals.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// String value of `--key`, if present.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.vals.get(key).map(|s| s.as_str())
    }

    /// Integer value of `--key`, or `default` when absent.
    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.vals.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} wants an integer, got {v:?}")),
        }
    }

    /// Float value of `--key`, or `default` when absent.
    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.vals.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} wants a number, got {v:?}")),
        }
    }

    /// Comma-separated float list value of `--key` (e.g.
    /// `--hetero 1,1,2`), or `None` when absent.
    pub fn f64_list(&self, key: &str) -> Result<Option<Vec<f64>>> {
        let Some(v) = self.vals.get(key) else {
            return Ok(None);
        };
        let mut out = Vec::new();
        for part in v.split(',') {
            let x: f64 = part.trim().parse().map_err(|_| {
                anyhow::anyhow!(
                    "--{key} wants comma-separated numbers, got {v:?}"
                )
            })?;
            out.push(x);
        }
        Ok(Some(out))
    }

    /// Whether the bare switch `--key` was passed.
    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    /// String value of `--key`, erroring when absent.
    pub fn require(&self, key: &str) -> Result<&str> {
        match self.vals.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing required flag --{key}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &[&str]) -> Flags {
        Flags::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
            .unwrap()
    }

    #[test]
    fn parses_values_switches_positionals() {
        let f = p(&["train", "--config", "base", "--fast", "--steps", "10"]);
        assert_eq!(f.positional, vec!["train"]);
        assert_eq!(f.str("config", "x"), "base");
        assert!(f.switch("fast"));
        assert_eq!(f.usize("steps", 0).unwrap(), 10);
        assert_eq!(f.usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn type_errors_reported() {
        let f = p(&["--steps", "abc"]);
        assert!(f.usize("steps", 0).is_err());
        assert!(f.require("nope").is_err());
    }

    #[test]
    fn float_lists() {
        let f = p(&["--hetero", "1,1.5, 2"]);
        assert_eq!(f.f64_list("hetero").unwrap(), Some(vec![1.0, 1.5, 2.0]));
        assert_eq!(f.f64_list("absent").unwrap(), None);
        let bad = p(&["--hetero", "1,x"]);
        assert!(bad.f64_list("hetero").is_err());
    }
}
