//! Synthetic corpora standing in for WikiText / BookCorpus / OpenWebText /
//! C4 (offline environment — see DESIGN.md §4 Substitutions).
//!
//! Each corpus is a deterministic mixture of an order-1 structured channel
//! (an affine next-token map, the learnable signal) and Zipfian unigram
//! noise. The mixture weight and Zipf exponent differ per corpus so the
//! four "datasets" have genuinely different difficulty, like the paper's.
//! Convergence-curve *shape* comparisons (compressed vs centralized vs
//! uncompressed-decentralized) are corpus-independent, which is what the
//! paper's figures assert.

use crate::rng::{Rng, Zipf};
use crate::tensor::IntTensor;

/// Which real dataset a synthetic corpus stands in for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusKind {
    /// WikiText stand-in: moderately structured
    Wiki,
    /// BookCorpus stand-in: highly structured (long-range repetition)
    Books,
    /// OpenWebText stand-in: noisier
    Web,
    /// C4 stand-in: noisiest / most diverse
    C4,
}

impl CorpusKind {
    /// Parse a CLI corpus label (several aliases per corpus).
    pub fn parse(s: &str) -> Option<CorpusKind> {
        Some(match s {
            "wiki" | "wikitext" | "wt" => CorpusKind::Wiki,
            "books" | "bookcorpus" | "bc" => CorpusKind::Books,
            "web" | "openwebtext" | "owt" => CorpusKind::Web,
            "c4" => CorpusKind::C4,
            _ => return None,
        })
    }

    /// Canonical dataset name (CSV labels).
    pub fn name(&self) -> &'static str {
        match self {
            CorpusKind::Wiki => "wikitext",
            CorpusKind::Books => "bookcorpus",
            CorpusKind::Web => "openwebtext",
            CorpusKind::C4 => "c4",
        }
    }

    /// (structured-channel probability, zipf exponent)
    fn params(&self) -> (f64, f64) {
        match self {
            CorpusKind::Books => (0.75, 1.2),
            CorpusKind::Wiki => (0.65, 1.1),
            CorpusKind::Web => (0.55, 1.05),
            CorpusKind::C4 => (0.45, 1.0),
        }
    }
}

/// A tokenized corpus with a train/validation split.
#[derive(Clone)]
pub struct Corpus {
    /// which dataset this stands in for
    pub kind: CorpusKind,
    /// vocabulary size
    pub vocab: usize,
    tokens: Vec<i32>,
    /// [0, split) = train, [split, len) = val
    split: usize,
}

impl Corpus {
    /// Deterministic synthetic corpus of `len` tokens.
    pub fn synthetic(kind: CorpusKind, vocab: usize, len: usize, seed: u64) -> Corpus {
        let (p_struct, zipf_s) = kind.params();
        let mut rng = Rng::new(seed ^ 0xC0DE);
        let zipf = Zipf::new(vocab, zipf_s);
        // affine next-token maps, one per "phase", switching occasionally —
        // gives the model mid-range structure to learn
        let phases: Vec<(usize, usize)> = (0..8)
            .map(|_| {
                // multiplier coprime-ish with vocab
                let a = 2 * rng.below(vocab / 2) + 1;
                let c = rng.below(vocab);
                (a, c)
            })
            .collect();
        let mut tokens = Vec::with_capacity(len);
        let mut prev = zipf.sample(&mut rng);
        let mut phase = 0usize;
        for i in 0..len {
            if i % 256 == 0 {
                phase = rng.below(phases.len());
            }
            let t = if rng.uniform() < p_struct {
                let (a, c) = phases[phase];
                (a * prev + c) % vocab
            } else {
                zipf.sample(&mut rng)
            };
            tokens.push(t as i32);
            prev = t;
        }
        let split = len * 9 / 10; // 10% validation (paper Sec. 8.1)
        Corpus { kind, vocab, tokens, split }
    }

    /// Total token count.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// Whether the corpus has no tokens.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    fn window(&self, lo: usize, hi: usize, n: usize, rng: &mut Rng) -> usize {
        debug_assert!(hi - lo > n + 1);
        lo + rng.below(hi - lo - n - 1)
    }

    /// Sample a (tokens, next-token targets) microbatch of shape (b, n)
    /// from the training split.
    pub fn train_batch(&self, b: usize, n: usize, rng: &mut Rng) -> (IntTensor, IntTensor) {
        self.batch_from(0, self.split, b, n, rng)
    }

    /// Sample from the validation split.
    pub fn val_batch(&self, b: usize, n: usize, rng: &mut Rng) -> (IntTensor, IntTensor) {
        self.batch_from(self.split, self.len(), b, n, rng)
    }

    fn batch_from(
        &self,
        lo: usize,
        hi: usize,
        b: usize,
        n: usize,
        rng: &mut Rng,
    ) -> (IntTensor, IntTensor) {
        let mut tok = Vec::with_capacity(b * n);
        let mut tgt = Vec::with_capacity(b * n);
        for _ in 0..b {
            let start = self.window(lo, hi, n, rng);
            tok.extend_from_slice(&self.tokens[start..start + n]);
            tgt.extend_from_slice(&self.tokens[start + 1..start + n + 1]);
        }
        (
            IntTensor::new(vec![b, n], tok),
            IntTensor::new(vec![b, n], tgt),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let a = Corpus::synthetic(CorpusKind::Wiki, 256, 10_000, 1);
        let b = Corpus::synthetic(CorpusKind::Wiki, 256, 10_000, 1);
        assert_eq!(a.tokens, b.tokens);
        let c = Corpus::synthetic(CorpusKind::Wiki, 256, 10_000, 2);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn tokens_in_vocab_range() {
        let c = Corpus::synthetic(CorpusKind::C4, 512, 50_000, 3);
        assert!(c.tokens.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn corpus_has_learnable_structure() {
        // bigram conditional entropy must be far below unigram entropy
        let c = Corpus::synthetic(CorpusKind::Books, 64, 200_000, 4);
        let v = c.vocab;
        let mut uni = vec![0f64; v];
        let mut bi = vec![0f64; v * v];
        for w in c.tokens.windows(2) {
            uni[w[0] as usize] += 1.0;
            bi[w[0] as usize * v + w[1] as usize] += 1.0;
        }
        let n = (c.tokens.len() - 1) as f64;
        let h_uni: f64 = uni
            .iter()
            .filter(|&&x| x > 0.0)
            .map(|&x| -(x / n) * (x / n).ln())
            .sum();
        let mut h_bi = 0.0;
        for p in 0..v {
            if uni[p] == 0.0 {
                continue;
            }
            for t in 0..v {
                let c2 = bi[p * v + t];
                if c2 > 0.0 {
                    h_bi += -(c2 / n) * (c2 / uni[p]).ln();
                }
            }
        }
        assert!(
            h_bi < 0.75 * h_uni,
            "bigram H {h_bi:.3} not ≪ unigram H {h_uni:.3}"
        );
    }

    #[test]
    fn batches_shapes_and_shift() {
        let c = Corpus::synthetic(CorpusKind::Web, 128, 20_000, 5);
        let mut rng = Rng::new(0);
        let (tok, tgt) = c.train_batch(4, 32, &mut rng);
        assert_eq!(tok.shape, vec![4, 32]);
        assert_eq!(tgt.shape, vec![4, 32]);
        // targets are inputs shifted by one
        for row in 0..4 {
            for i in 0..31 {
                assert_eq!(tok.data[row * 32 + i + 1], tgt.data[row * 32 + i]);
            }
        }
    }

    #[test]
    fn corpora_difficulty_ordering() {
        // books (most structured) should have lower bigram entropy than c4
        fn bigram_h(kind: CorpusKind) -> f64 {
            let c = Corpus::synthetic(kind, 64, 100_000, 6);
            let v = c.vocab;
            let mut uni = vec![0f64; v];
            let mut bi = vec![0f64; v * v];
            for w in c.tokens.windows(2) {
                uni[w[0] as usize] += 1.0;
                bi[w[0] as usize * v + w[1] as usize] += 1.0;
            }
            let n = (c.tokens.len() - 1) as f64;
            let mut h = 0.0;
            for p in 0..v {
                for t in 0..v {
                    let c2 = bi[p * v + t];
                    if c2 > 0.0 {
                        h += -(c2 / n) * (c2 / uni[p]).ln();
                    }
                }
            }
            h
        }
        assert!(bigram_h(CorpusKind::Books) < bigram_h(CorpusKind::C4));
    }
}
