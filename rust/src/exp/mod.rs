//! Experiment drivers: one per paper figure/table (DESIGN.md §5).
//!
//! Each driver trains real models through the coordinator and emits CSV
//! series under `results/` with the same rows/curves the paper reports.
//! `--fast` presets shrink step counts so the full suite runs on CPU in
//! minutes; absolute numbers differ from the paper (simulated substrate),
//! the *shape* — who wins, by what factor, where crossovers fall — is the
//! reproduction target.
//!
//! Execution model (DESIGN.md §8): every grid/sweep driver expresses its
//! cells as pure `RunSpec → Row` jobs executed on the [`crate::par`]
//! worker pool. Each job owns its whole world — pipeline, PJRT runtime,
//! corpus, per-run CSV log — inside one pool worker, and derives any
//! randomness independently of pool scheduling: from `opts.seed` (plus
//! fixed per-driver constants), or from
//! [`crate::par::cell_seed`]`(opts.seed, index)` where a driver wants
//! per-cell independent streams. Summary rows are written serially in
//! submission order after the pool drains, so the emitted CSVs are
//! **byte-identical** at `--threads 1` and `--threads N`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::compress::Mode;
use crate::coordinator::replica::{simulate_hybrid_step, HybridSimSpec};
use crate::coordinator::{Pipeline, PipelineConfig};
use crate::data::{Corpus, CorpusKind};
use crate::linalg;
use crate::manifest::{Hyper, Manifest};
use crate::memory;
use crate::metrics::{perplexity, CsvWriter, RunLog};
use crate::netsim::{LinkSpec, Topology, MBPS};
use crate::par;
use crate::rng::Rng;
use crate::sim::{simulate_swarm, ChurnSpec, Schedule, SwarmSpec};
use crate::tensor::Tensor;
use crate::timemodel::TimeModel;

/// Shared experiment options.
#[derive(Clone, Debug)]
pub struct ExpOpts {
    /// AOT artifact directory (manifest.json + HLO text)
    pub artifacts: PathBuf,
    /// output directory for CSV series
    pub out_dir: PathBuf,
    /// shrink presets so the suite runs in minutes on CPU
    pub fast: bool,
    /// explicit step-count override
    pub steps: Option<usize>,
    /// master seed
    pub seed: u64,
    /// worker-pool width for grid cells (0 = all available cores)
    pub threads: usize,
    /// use the exact O(d³) Jacobi stable rank on the metrics cadence
    /// instead of the randomized O(d²r) estimator (`--exact-rank`)
    pub exact_rank: bool,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            artifacts: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("results"),
            fast: false,
            steps: None,
            seed: 17,
            threads: 0,
            exact_rank: false,
        }
    }
}

impl ExpOpts {
    fn steps_or(&self, full: usize, fast: usize) -> usize {
        self.steps.unwrap_or(if self.fast { fast } else { full })
    }

    fn manifest(&self) -> Result<Manifest> {
        Manifest::load(&self.artifacts)
    }

    /// Pool width for this run's grid cells.
    fn pool_threads(&self) -> usize {
        if self.threads == 0 {
            par::max_threads()
        } else {
            self.threads
        }
    }

    /// Stable rank on the metrics cadence: randomized range-finder by
    /// default, exact Jacobi behind `--exact-rank`.
    fn stable_rank(&self, t: &Tensor) -> f64 {
        if self.exact_rank {
            linalg::stable_rank(t)
        } else {
            linalg::stable_rank_approx(t, linalg::STABLE_RANK_SKETCH)
        }
    }
}

fn topo_for(bw: &str, stages: usize, rng: &mut Rng) -> Result<Topology> {
    let spec = LinkSpec::parse(bw)
        .ok_or_else(|| anyhow::anyhow!("bad bandwidth {bw:?}"))?;
    Ok(Topology::uniform(stages, spec, rng))
}

/// One grid cell: everything a pool worker needs to train one system
/// end-to-end, independent of every other cell.
#[derive(Clone, Debug)]
struct RunSpec {
    label: String,
    config: String,
    mode: Mode,
    bandwidth: String,
    microbatches: usize,
    grassmann: usize,
    lr: f32,
    corpus: CorpusKind,
}

/// Train one system for `steps`, logging a full curve; returns
/// (final val ppl, tokens/sim-second, cumulative sim seconds).
/// Runs self-contained inside one pool worker: the pipeline owns its
/// runtime, and all randomness derives from `opts.seed` (identical for
/// any pool width).
fn run_one(
    opts: &ExpOpts,
    m: &Manifest,
    spec: &RunSpec,
    steps: usize,
    sub_dir: &str,
) -> Result<(f64, f64, f64)> {
    let cm = m.config(&spec.config)?;
    let h = cm.hyper.clone();
    let mut rng = Rng::new(opts.seed);
    let topo = topo_for(&spec.bandwidth, h.stages, &mut rng)?;
    let pcfg = PipelineConfig {
        mode: spec.mode,
        microbatches: spec.microbatches,
        grassmann_interval: spec.grassmann,
        lr: spec.lr,
        warmup_steps: (steps / 20).max(5),
        total_steps: steps,
        time_model: TimeModel::default_analytic(),
        seed: opts.seed,
        ..Default::default()
    };
    let mut pipe = Pipeline::new(m, &spec.config, topo, pcfg)?;
    let corpus =
        Corpus::synthetic(spec.corpus, h.vocab, 400_000, opts.seed ^ 0xDD);
    let mut log = RunLog::create(opts.out_dir.join(sub_dir), &spec.label)?;
    for step in 0..steps {
        let stats = pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
        log.log(&stats)?;
        if step % 20 == 0 {
            eprintln!(
                "[{}] step {step}/{steps} loss {:.4} sim_t {:.3}s",
                spec.label, stats.loss, log.sim_time
            );
        }
    }
    let val = pipe.eval(4, |r| corpus.val_batch(h.b, h.n, r))?;
    let tps = log.tps();
    let sim = log.sim_time;
    log.finish()?;
    Ok((perplexity(val), tps, sim))
}

/// Train until the simulated clock passes `budget_s` (Table 1's
/// fixed-wall-clock protocol). Returns (val ppl, tps, steps done).
fn run_budget(
    opts: &ExpOpts,
    m: &Manifest,
    spec: &RunSpec,
    budget_s: f64,
    max_steps: usize,
    sub_dir: &str,
) -> Result<(f64, f64, usize)> {
    let cm = m.config(&spec.config)?;
    let h = cm.hyper.clone();
    let mut rng = Rng::new(opts.seed);
    let topo = topo_for(&spec.bandwidth, h.stages, &mut rng)?;
    let pcfg = PipelineConfig {
        mode: spec.mode,
        microbatches: spec.microbatches,
        grassmann_interval: spec.grassmann,
        lr: spec.lr,
        warmup_steps: 10,
        total_steps: max_steps,
        time_model: TimeModel::default_analytic(),
        seed: opts.seed,
        ..Default::default()
    };
    let mut pipe = Pipeline::new(m, &spec.config, topo, pcfg)?;
    let corpus =
        Corpus::synthetic(spec.corpus, h.vocab, 400_000, opts.seed ^ 0xDD);
    let mut log = RunLog::create(opts.out_dir.join(sub_dir), &spec.label)?;
    let mut steps = 0;
    while log.sim_time < budget_s && steps < max_steps {
        let stats = pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
        log.log(&stats)?;
        steps += 1;
    }
    let val = pipe.eval(4, |r| corpus.val_batch(h.b, h.n, r))?;
    let tps = log.tps();
    log.finish()?;
    Ok((perplexity(val), tps, steps))
}

/// Run every spec as a pool job (`run_one` per cell); results come back
/// in submission order.
fn run_specs(
    opts: &ExpOpts,
    m: &Manifest,
    specs: &[RunSpec],
    steps: usize,
    sub_dir: &str,
) -> Result<Vec<(f64, f64, f64)>> {
    par::try_map(opts.pool_threads(), specs, |_, spec| {
        run_one(opts, m, spec, steps, sub_dir)
    })
}

// ---------------------------------------------------------------------------
// Figs. 1, 7, 16 — rank collapse
// ---------------------------------------------------------------------------

/// Figs. 1/7: stable-rank trajectories of constrained weights (or
/// gradients with `grads`) during non-compressed training.
pub fn rank_collapse(opts: &ExpOpts, grads: bool) -> Result<()> {
    let m = opts.manifest()?;
    let config = if opts.fast { "tiny" } else { "small" };
    let cm = m.config(config)?;
    let h = cm.hyper.clone();
    let steps = opts.steps_or(400, 80);
    let mut rng = Rng::new(opts.seed);
    let topo = topo_for("100gbps", h.stages, &mut rng)?;
    let pcfg = PipelineConfig {
        mode: Mode::Raw, // the paper's Fig. 1 tracks a NON-compressed model
        microbatches: 4,
        grassmann_interval: 0,
        lr: 1e-2,
        warmup_steps: 10,
        total_steps: steps,
        record_grads: grads,
        ..Default::default()
    };
    let mut pipe = Pipeline::new(&m, config, topo, pcfg)?;
    let corpus = Corpus::synthetic(CorpusKind::Wiki, h.vocab, 400_000, 3);
    let what = if grads { "grads" } else { "weights" };
    let mut csv = CsvWriter::create(
        opts.out_dir.join(format!("fig1_rank_collapse_{what}.csv")),
        &["step", "stage", "param", "stable_rank", "max_rank"],
    )?;
    let every = (steps / 20).max(1);
    for step in 0..steps {
        pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
        if step % every != 0 && step + 1 != steps {
            continue;
        }
        for (si, st) in pipe.stages.iter().enumerate() {
            for ((name, shape), idx) in
                st.schema.iter().zip(0..st.params.len())
            {
                if !(name.ends_with("wp1") || name.ends_with("wp2")) {
                    continue;
                }
                let t: &Tensor = if grads {
                    match &pipe.last_grads {
                        Some(g) => &g[si][idx],
                        None => continue,
                    }
                } else {
                    &st.params[idx]
                };
                let sr = opts.stable_rank(t);
                let max_rank = shape.iter().copied().min().unwrap_or(0);
                csv.row(&[
                    step.to_string(),
                    si.to_string(),
                    name.clone(),
                    format!("{sr:.4}"),
                    max_rank.to_string(),
                ])?;
            }
        }
    }
    csv.finish()?;
    Ok(())
}

/// Fig. 16 stand-in: stable ranks of *trained* checkpoints across scales
/// (official frontier checkpoints are unavailable offline — DESIGN.md §4).
pub fn checkpoint_ranks(opts: &ExpOpts) -> Result<()> {
    let m = opts.manifest()?;
    let steps = opts.steps_or(200, 40);
    let mut csv = CsvWriter::create(
        opts.out_dir.join("fig16_checkpoint_ranks.csv"),
        &["config", "stage", "param", "stable_rank", "normalized"],
    )?;
    let configs = ["tiny", "small"];
    // one trained pipeline per config, in parallel; rank rows extracted
    // serially afterwards so the CSV order is fixed
    let pipes = par::try_map(opts.pool_threads(), &configs, |_, config| {
        let cm = m.config(config)?;
        let h = cm.hyper.clone();
        let mut rng = Rng::new(opts.seed);
        let topo = topo_for("100gbps", h.stages, &mut rng)?;
        let pcfg = PipelineConfig {
            mode: Mode::Raw,
            microbatches: 4,
            grassmann_interval: 0,
            lr: 1e-2,
            warmup_steps: 10,
            total_steps: steps,
            ..Default::default()
        };
        let mut pipe = Pipeline::new(&m, config, topo, pcfg)?;
        let corpus = Corpus::synthetic(CorpusKind::Wiki, h.vocab, 400_000, 5);
        for _ in 0..steps {
            pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
        }
        let mut rows: Vec<[String; 5]> = Vec::new();
        for (si, st) in pipe.stages.iter().enumerate() {
            for ((name, shape), p) in st.schema.iter().zip(&st.params) {
                if !name.ends_with("wp2") {
                    continue;
                }
                let sr = opts.stable_rank(p);
                let maxr = shape.iter().copied().min().unwrap() as f64;
                rows.push([
                    config.to_string(),
                    si.to_string(),
                    name.clone(),
                    format!("{sr:.4}"),
                    format!("{:.4}", sr / maxr),
                ]);
            }
        }
        Ok(rows)
    })?;
    for rows in pipes {
        for r in rows {
            csv.row(&r)?;
        }
    }
    csv.finish()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 2 — convergence in low-bandwidth settings (3 corpora × 3 systems)
// ---------------------------------------------------------------------------

/// Fig. 2: convergence curves in low-bandwidth settings, three systems
/// per corpus.
pub fn convergence_bandwidth(opts: &ExpOpts) -> Result<()> {
    let m = opts.manifest()?;
    let config = if opts.fast { "small" } else { "base" };
    let steps = opts.steps_or(300, 60);
    let corpora = if opts.fast {
        vec![CorpusKind::Wiki]
    } else {
        vec![CorpusKind::Web, CorpusKind::Wiki, CorpusKind::Books]
    };
    let mut specs = Vec::new();
    for corpus in corpora {
        for (label, mode, bw) in [
            ("decentralized_compressed_80mbps", Mode::Subspace, "80mbps"),
            ("decentralized_raw_80mbps", Mode::Raw, "80mbps"),
            ("centralized_raw_100gbps", Mode::Raw, "100gbps"),
        ] {
            specs.push(RunSpec {
                label: format!("{}_{}", corpus.name(), label),
                config: config.to_string(),
                mode,
                bandwidth: bw.into(),
                microbatches: 8,
                grassmann: 0,
                lr: 6e-3,
                corpus,
            });
        }
    }
    run_specs(opts, &m, &specs, steps, "fig2_convergence")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Figs. 3 / 12 — performance against depth
// ---------------------------------------------------------------------------

/// Figs. 3/12: compressed-vs-centralized performance against pipeline
/// depth.
pub fn depth_sweep(opts: &ExpOpts) -> Result<()> {
    let m = opts.manifest()?;
    let steps = opts.steps_or(200, 50);
    let configs: &[&str] =
        if opts.fast { &["small"] } else { &["small", "base", "deep16"] };
    let mut specs = Vec::new();
    for config in configs {
        let layers = m.config(config)?.hyper.layers;
        for (label, mode, bw) in [
            ("compressed_80mbps", Mode::Subspace, "80mbps"),
            ("centralized_100gbps", Mode::Raw, "100gbps"),
        ] {
            specs.push(RunSpec {
                label: format!("layers{layers}_{label}"),
                config: config.to_string(),
                mode,
                bandwidth: bw.into(),
                microbatches: 4,
                grassmann: 0,
                lr: 6e-3,
                corpus: CorpusKind::C4,
            });
        }
    }
    run_specs(opts, &m, &specs, steps, "fig3_depth")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Figs. 4 / 13 — throughput gain vs bandwidth (training + inference)
// ---------------------------------------------------------------------------

/// Figs. 4/13: training + inference throughput gain vs link bandwidth.
pub fn throughput_sweep(opts: &ExpOpts) -> Result<()> {
    let m = opts.manifest()?;
    let config = if opts.fast { "small" } else { "base" };
    let cm = m.config(config)?;
    let h = cm.hyper.clone();
    let bws = ["10mbps", "80mbps", "500mbps", "1000mbps", "16gbps", "100gbps"];
    let mbs = if opts.fast { 4 } else { 8 };
    // one cell per (bandwidth × mode): returns (train tps, inference tps)
    let mut cells: Vec<(&str, Mode)> = Vec::new();
    for bw in bws {
        for mode in [Mode::Subspace, Mode::Raw] {
            cells.push((bw, mode));
        }
    }
    let measured =
        par::try_map(opts.pool_threads(), &cells, |_, (bw, mode)| {
            let mut rng = Rng::new(opts.seed);
            let topo = topo_for(bw, h.stages, &mut rng)?;
            let pcfg = PipelineConfig {
                mode: *mode,
                microbatches: mbs,
                grassmann_interval: 0,
                total_steps: 10,
                ..Default::default()
            };
            let mut pipe = Pipeline::new(&m, config, topo, pcfg)?;
            let corpus =
                Corpus::synthetic(CorpusKind::C4, h.vocab, 200_000, 7);
            // training throughput: a few steps
            let mut t_train = 0.0;
            let mut toks = 0usize;
            for _ in 0..3 {
                let s =
                    pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
                t_train += s.sim_seconds;
                toks += s.tokens;
            }
            // inference throughput
            let (t_inf, toks_inf) = pipe
                .forward_throughput(mbs * 3, |r| corpus.val_batch(h.b, h.n, r))?;
            Ok((toks as f64 / t_train, toks_inf as f64 / t_inf))
        })?;
    // key results by (bandwidth, mode, phase) — robust against any
    // reordering or extension of the cell construction above
    let mut tps: std::collections::BTreeMap<(&str, &str, &str), f64> =
        Default::default();
    for ((bw, mode), (train, inference)) in cells.iter().zip(&measured) {
        tps.insert((*bw, mode.as_str(), "train"), *train);
        tps.insert((*bw, mode.as_str(), "inference"), *inference);
    }
    let mut csv = CsvWriter::create(
        opts.out_dir.join("fig4_throughput.csv"),
        &["bandwidth", "mode", "phase", "tokens_per_second", "gain_vs_raw"],
    )?;
    for bw in bws {
        for phase in ["train", "inference"] {
            let raw = tps[&(bw, "raw", phase)];
            for mode in ["subspace", "raw"] {
                let v = tps[&(bw, mode, phase)];
                csv.row(&[
                    bw.to_string(),
                    mode.to_string(),
                    phase.to_string(),
                    format!("{v:.2}"),
                    format!("{:.3}", v / raw),
                ])?;
            }
        }
    }
    csv.finish()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 5 — globally distributed regions vs same-region centralized
// ---------------------------------------------------------------------------

/// Which topology a `global_regions` cell builds (from its own seed).
#[derive(Clone, Copy, Debug)]
enum RegionTopo {
    Global,
    Centralized16g,
}

/// Fig. 5: four-region global deployment vs same-region centralized.
pub fn global_regions(opts: &ExpOpts) -> Result<()> {
    let m = opts.manifest()?;
    let config = if opts.fast { "small" } else { "deep16" };
    let cm = m.config(config)?;
    let h = cm.hyper.clone();
    let steps = opts.steps_or(200, 50);
    let cells: Vec<(&str, Mode, RegionTopo)> = vec![
        (
            "decentralized_4regions_compressed",
            Mode::Subspace,
            RegionTopo::Global,
        ),
        ("decentralized_4regions_raw", Mode::Raw, RegionTopo::Global),
        (
            "centralized_16gbps_raw",
            Mode::Raw,
            RegionTopo::Centralized16g,
        ),
    ];
    let rows = par::try_map(
        opts.pool_threads(),
        &cells,
        |i, (label, mode, which)| {
            // per-cell topology stream: (seed, cell) only — stable under
            // any pool width
            let mut rng = Rng::new(par::cell_seed(opts.seed, i));
            let topo = match which {
                RegionTopo::Global => {
                    Topology::global_regions(h.stages, &mut rng)
                }
                RegionTopo::Centralized16g => Topology::uniform(
                    h.stages,
                    LinkSpec::centralized_16g(),
                    &mut rng,
                ),
            };
            let pcfg = PipelineConfig {
                mode: *mode,
                microbatches: 16, // deep pipeline: amortize the fill
                grassmann_interval: 0,
                lr: 6e-3,
                warmup_steps: 10,
                total_steps: steps,
                seed: opts.seed,
                ..Default::default()
            };
            let mut pipe = Pipeline::new(&m, config, topo, pcfg)?;
            let corpus =
                Corpus::synthetic(CorpusKind::C4, h.vocab, 400_000, opts.seed);
            let mut log = RunLog::create(
                opts.out_dir.join("fig5_global_regions"),
                label,
            )?;
            for _ in 0..steps {
                let s =
                    pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
                log.log(&s)?;
            }
            let row = [
                label.to_string(),
                format!("{:.4}", log.last_loss),
                format!("{:.1}", log.tps()),
                format!("{:.2}", log.sim_time),
            ];
            log.finish()?;
            Ok(row)
        },
    )?;
    let mut summary = CsvWriter::create(
        opts.out_dir.join("fig5_global_regions_summary.csv"),
        &["system", "final_loss", "tokens_per_second", "sim_seconds"],
    )?;
    for row in &rows {
        summary.row(row)?;
    }
    summary.finish()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 6 — lossy compression baselines at matched ratio
// ---------------------------------------------------------------------------

/// Fig. 6: lossy compression baselines at matched wire ratio.
pub fn lossy_comparison(opts: &ExpOpts) -> Result<()> {
    let m = opts.manifest()?;
    let config = if opts.fast { "tiny" } else { "small" };
    let steps = opts.steps_or(250, 60);
    let specs: Vec<RunSpec> = [
        ("ours_subspace", Mode::Subspace),
        ("uncompressed", Mode::Raw),
        ("topk", Mode::TopK),
        ("quant_int8", Mode::Quant),
        ("lowrank_power", Mode::PowerLR),
    ]
    .iter()
    .map(|(label, mode)| RunSpec {
        label: (*label).into(),
        config: config.to_string(),
        mode: *mode,
        bandwidth: "100gbps".into(), // isolate compression error
        microbatches: 8,
        grassmann: 0,
        lr: if config == "tiny" { 1e-2 } else { 6e-3 },
        corpus: CorpusKind::Wiki,
    })
    .collect();
    run_specs(opts, &m, &specs, steps, "fig6_lossy")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Figs. 8/9 — batch-size ablation; Figs. 10/11 — context-length ablation
// ---------------------------------------------------------------------------

/// Figs. 8/9: batch-size ablation.
pub fn batch_sweep(opts: &ExpOpts) -> Result<()> {
    let m = opts.manifest()?;
    let config = "small";
    let steps = opts.steps_or(200, 50);
    let b = m.config(config)?.hyper.b;
    let mut specs = Vec::new();
    for mbs in [2usize, 4, 8] {
        for (label, mode, bw) in [
            ("compressed_80mbps", Mode::Subspace, "80mbps"),
            ("centralized_100gbps", Mode::Raw, "100gbps"),
        ] {
            specs.push(RunSpec {
                label: format!("batch{}_{label}", mbs * b),
                config: config.to_string(),
                mode,
                bandwidth: bw.into(),
                microbatches: mbs,
                grassmann: 0,
                lr: 6e-3,
                corpus: CorpusKind::C4,
            });
        }
    }
    run_specs(opts, &m, &specs, steps, "fig8_batch")?;
    Ok(())
}

/// Figs. 10/11: context-length ablation.
pub fn context_sweep(opts: &ExpOpts) -> Result<()> {
    let m = opts.manifest()?;
    let steps = opts.steps_or(200, 50);
    let mut specs = Vec::new();
    for config in ["small", "ctx128", "ctx256"] {
        let n = m.config(config)?.hyper.n;
        for (label, mode, bw) in [
            ("compressed_80mbps", Mode::Subspace, "80mbps"),
            ("centralized_100gbps", Mode::Raw, "100gbps"),
        ] {
            specs.push(RunSpec {
                label: format!("ctx{n}_{label}"),
                config: config.to_string(),
                mode,
                bandwidth: bw.into(),
                microbatches: 4,
                grassmann: 0,
                lr: 6e-3,
                corpus: CorpusKind::C4,
            });
        }
    }
    run_specs(opts, &m, &specs, steps, "fig10_context")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 14 — Grassmann subspace updates; Fig. 15 — embedding decomposition
// ---------------------------------------------------------------------------

/// Fig. 14: Grassmann subspace-update ablation.
pub fn grassmann_ablation(opts: &ExpOpts) -> Result<()> {
    let m = opts.manifest()?;
    let config = if opts.fast { "tiny" } else { "small" };
    let steps = opts.steps_or(300, 80);
    let specs: Vec<RunSpec> =
        [("no_subspace_updates", 0usize), ("with_subspace_updates", 25)]
            .iter()
            .map(|(label, interval)| RunSpec {
                label: (*label).into(),
                config: config.to_string(),
                mode: Mode::Subspace,
                bandwidth: "80mbps".into(),
                microbatches: 8,
                grassmann: *interval,
                lr: if config == "tiny" { 1e-2 } else { 6e-3 },
                corpus: CorpusKind::C4,
            })
            .collect();
    run_specs(opts, &m, &specs, steps, "fig14_grassmann")?;
    Ok(())
}

/// Fig. 15: embedding-decomposition (nofixed) ablation.
pub fn embedding_ablation(opts: &ExpOpts) -> Result<()> {
    let m = opts.manifest()?;
    let config = "small"; // nofixed entries are compiled for small
    let steps = opts.steps_or(250, 60);
    let specs: Vec<RunSpec> = [
        ("with_fixed_high_rank_embedding", Mode::Subspace),
        ("embedding_fully_in_subspace", Mode::NoFixed),
    ]
    .iter()
    .map(|(label, mode)| RunSpec {
        label: (*label).into(),
        config: config.to_string(),
        mode: *mode,
        bandwidth: "80mbps".into(),
        microbatches: 8,
        grassmann: 0,
        lr: 6e-3,
        corpus: CorpusKind::C4,
    })
    .collect();
    run_specs(opts, &m, &specs, steps, "fig15_embedding")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 1 — perplexity after a fixed wall-clock budget; Table 2 — compute-
// optimal training
// ---------------------------------------------------------------------------

/// Table 1: perplexity after a fixed simulated wall-clock budget.
pub fn table1(opts: &ExpOpts) -> Result<()> {
    let m = opts.manifest()?;
    let config = if opts.fast { "tiny" } else { "small" };
    // simulated seconds standing in for the paper's 12 h
    let budget = if opts.fast { 0.6 } else { 3.0 };
    let max_steps = opts.steps_or(600, 150);
    let corpora = if opts.fast {
        vec![CorpusKind::Wiki]
    } else {
        vec![CorpusKind::Web, CorpusKind::Books, CorpusKind::Wiki]
    };
    let mut cells: Vec<(CorpusKind, &str, Mode, &str)> = Vec::new();
    for corpus in corpora {
        for (system, mode, bw) in [
            ("decentralized_compressed", Mode::Subspace, "80mbps"),
            ("decentralized_raw", Mode::Raw, "80mbps"),
            ("centralized", Mode::Raw, "100gbps"),
        ] {
            cells.push((corpus, system, mode, bw));
        }
    }
    let rows = par::try_map(
        opts.pool_threads(),
        &cells,
        |_, (corpus, system, mode, bw)| {
            let spec = RunSpec {
                label: format!("{}_{system}", corpus.name()),
                config: config.to_string(),
                mode: *mode,
                bandwidth: (*bw).into(),
                microbatches: 8,
                grassmann: 0,
                lr: if config == "tiny" { 1e-2 } else { 6e-3 },
                corpus: *corpus,
            };
            let (ppl, tps, steps) =
                run_budget(opts, &m, &spec, budget, max_steps, "table1_runs")?;
            Ok([
                system.to_string(),
                bw.to_string(),
                corpus.name().to_string(),
                format!("{ppl:.2}"),
                format!("{tps:.1}"),
                steps.to_string(),
            ])
        },
    )?;
    let mut csv = CsvWriter::create(
        opts.out_dir.join("table1_perplexity.csv"),
        &["system", "bandwidth", "corpus", "val_ppl", "tps", "steps"],
    )?;
    for row in &rows {
        csv.row(row)?;
    }
    csv.finish()?;
    Ok(())
}

/// Table 2: compute-optimal (Chinchilla-ratio) training comparison.
pub fn table2(opts: &ExpOpts) -> Result<()> {
    let m = opts.manifest()?;
    let config = if opts.fast { "tiny" } else { "small" };
    let cm = m.config(config)?;
    let h = cm.hyper.clone();
    // Chinchilla 1:20 params:tokens (scaled by --fast)
    let token_target = cm.hyper.param_count * if opts.fast { 2 } else { 20 };
    let mbs = 8usize;
    let steps = (token_target / (mbs * h.b * h.n)).max(20);
    let mut cells: Vec<(&str, Mode, &str, CorpusKind)> = Vec::new();
    for (system, mode, bw) in [
        ("decentralized_compressed", Mode::Subspace, "80mbps"),
        ("centralized", Mode::Raw, "100gbps"),
    ] {
        for corpus in [CorpusKind::C4, CorpusKind::Books] {
            cells.push((system, mode, bw, corpus));
        }
    }
    let specs: Vec<RunSpec> = cells
        .iter()
        .map(|(system, mode, bw, corpus)| RunSpec {
            label: format!("t2_{}_{system}", corpus.name()),
            config: config.to_string(),
            mode: *mode,
            bandwidth: (*bw).into(),
            microbatches: mbs,
            grassmann: 0,
            lr: if config == "tiny" { 1e-2 } else { 6e-3 },
            corpus: *corpus,
        })
        .collect();
    let results = run_specs(opts, &m, &specs, steps, "table2_runs")?;
    let mut csv = CsvWriter::create(
        opts.out_dir.join("table2_compute_optimal.csv"),
        &["system", "corpus", "val_ppl", "tps", "tokens"],
    )?;
    for ((system, _, _, corpus), (ppl, tps, _)) in
        cells.iter().zip(&results)
    {
        csv.row(&[
            system.to_string(),
            corpus.name().to_string(),
            format!("{ppl:.2}"),
            format!("{tps:.1}"),
            (steps * mbs * h.b * h.n).to_string(),
        ])?;
    }
    // the raw decentralized system is infeasible to train to compute-
    // optimal (paper: est. 200 days) — report TPS only, like the paper
    let mut rng = Rng::new(opts.seed);
    let topo = topo_for("80mbps", h.stages, &mut rng)?;
    let pcfg = PipelineConfig {
        mode: Mode::Raw,
        microbatches: mbs,
        total_steps: 3,
        ..Default::default()
    };
    let mut pipe = Pipeline::new(&m, config, topo, pcfg)?;
    let corpus = Corpus::synthetic(CorpusKind::C4, h.vocab, 200_000, 9);
    let mut t = 0.0;
    let mut toks = 0;
    for _ in 0..3 {
        let s = pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
        t += s.sim_seconds;
        toks += s.tokens;
    }
    csv.row(&[
        "decentralized_raw".into(),
        "-".into(),
        "-".into(),
        format!("{:.1}", toks as f64 / t),
        "-".into(),
    ])?;
    csv.finish()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Tables 3 / 4 — memory overhead (analytic model at paper dims)
// ---------------------------------------------------------------------------

/// Table 3: peak-memory model against sequence length.
pub fn memory_seqlen(opts: &ExpOpts) -> Result<()> {
    let mut csv = CsvWriter::create(
        opts.out_dir.join("table3_memory_seqlen.csv"),
        &["L", "baseline_gb", "ours_gb", "overhead_mb", "relative_pct"],
    )?;
    for l in [8192usize, 16384, 24576] {
        let r = memory::table_row(l, 1);
        csv.row(&[
            l.to_string(),
            format!("{:.2}", r.baseline_gb),
            format!("{:.2}", r.ours_gb),
            format!("{:.0}", r.overhead_mb),
            format!("{:.2}", r.relative * 100.0),
        ])?;
    }
    csv.finish()?;
    Ok(())
}

/// Table 4: peak-memory model against context-parallel worker count.
pub fn memory_workers(opts: &ExpOpts) -> Result<()> {
    let mut csv = CsvWriter::create(
        opts.out_dir.join("table4_memory_workers.csv"),
        &["L", "workers", "baseline_gb", "ours_gb", "overhead_per_worker_mb",
          "relative_pct"],
    )?;
    for (l, w) in [(8192usize, 1usize), (16384, 1), (24576, 1), (49152, 2),
                   (65536, 3)] {
        let r = memory::table_row(l, w);
        csv.row(&[
            l.to_string(),
            w.to_string(),
            format!("{:.2}", r.baseline_gb),
            format!("{:.2}", r.ours_gb),
            format!("{:.0}", r.overhead_mb),
            format!("{:.2}", r.relative * 100.0),
        ])?;
    }
    csv.finish()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// replicated pipelines — bandwidth × replicas hybrid-parallelism grid
// ---------------------------------------------------------------------------

/// Hybrid data-parallel × model-parallel grid (DESIGN.md §6): for each
/// (replicas, bandwidth) cell, price one step of R replicated pipelines
/// with the cross-replica weight-gradient all-reduce under every dp-mode,
/// using the analytic cost model — no AOT artifacts required. Cells run
/// on the worker pool; rows land in submission order. Emits
/// `fig_dp_grid.csv` with the step makespan, the non-overlapped
/// all-reduce tail, and the per-link gradient bytes.
pub fn dp_grid(opts: &ExpOpts) -> Result<()> {
    let hyper = if opts.fast { Hyper::small_sim() } else { Hyper::base_sim() };
    let replicas: &[usize] = if opts.fast { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let bws_mbps: &[f64] =
        if opts.fast { &[80.0, 1000.0] } else { &[10.0, 80.0, 300.0, 1000.0, 16000.0] };
    let mut cells: Vec<(usize, f64, Mode)> = Vec::new();
    for &r in replicas {
        for &bw in bws_mbps {
            for dp_mode in [Mode::Subspace, Mode::Quant, Mode::TopK, Mode::Raw] {
                cells.push((r, bw, dp_mode));
            }
        }
    }
    let rows =
        par::try_map(opts.pool_threads(), &cells, |_, (r, bw, dp_mode)| {
            let mut spec =
                HybridSimSpec::uniform(hyper.clone(), *r, bw * MBPS);
            spec.dp_mode = *dp_mode;
            spec.seed = opts.seed;
            let res = simulate_hybrid_step(&spec);
            let tokens = (r * spec.microbatches * hyper.b * hyper.n) as f64;
            Ok([
                r.to_string(),
                format!("{bw}"),
                dp_mode.as_str().to_string(),
                format!("{:.6}", res.makespan.total),
                format!("{:.6}", res.makespan.compute_end),
                format!("{:.6}", res.makespan.tail),
                res.dp_bytes_per_link.to_string(),
                format!("{:.1}", tokens / res.makespan.total.max(1e-12)),
            ])
        })?;
    let mut csv = CsvWriter::create(
        opts.out_dir.join("fig_dp_grid.csv"),
        &[
            "replicas",
            "bandwidth_mbps",
            "dp_mode",
            "step_seconds",
            "pipeline_seconds",
            "allreduce_tail_seconds",
            "dp_bytes_per_link",
            "tokens_per_sim_second",
        ],
    )?;
    for row in &rows {
        csv.row(row)?;
    }
    csv.finish()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// native autodiff backend — measured convergence under every boundary codec
// ---------------------------------------------------------------------------

/// Native-backend convergence grid (DESIGN.md §10): train the tiny
/// transformer *numerically* on the in-process autodiff backend under
/// every boundary scheme — the paper's headline convergence-parity claim
/// measured per step instead of priced in bytes. One pool cell per mode;
/// each cell logs a full per-step loss curve under
/// `fig_native_convergence/` plus one summary row with the final
/// train/val loss and the real wire bytes a boundary payload occupied.
/// Artifact-free and PJRT-free; byte-identical CSVs at any `--threads`.
pub fn convergence_native(opts: &ExpOpts) -> Result<()> {
    use crate::nn::{NativePipeline, Optim};

    let h = Hyper::tiny_native();
    let steps = opts.steps_or(200, 12);
    let modes: &[Mode] = if opts.fast {
        &[Mode::Subspace, Mode::Raw, Mode::TopK, Mode::Quant]
    } else {
        &[
            Mode::Subspace,
            Mode::Raw,
            Mode::TopK,
            Mode::Quant,
            Mode::PowerLR,
            Mode::NoFixed,
            Mode::RawBf16,
            Mode::SubspaceBf16,
        ]
    };
    let rows = par::try_map(opts.pool_threads(), modes, |_, mode| {
        let mut rng = Rng::new(opts.seed);
        let topo = topo_for("80mbps", h.stages, &mut rng)?;
        let pcfg = PipelineConfig {
            mode: *mode,
            microbatches: 4,
            grassmann_interval: 0,
            lr: 1e-2,
            warmup_steps: (steps / 20).max(5),
            total_steps: steps,
            time_model: TimeModel::default_analytic(),
            seed: opts.seed,
            ..Default::default()
        };
        let mut pipe =
            NativePipeline::new(h.clone(), topo, pcfg, Optim::AdamW)?;
        let corpus = Corpus::synthetic(
            CorpusKind::Wiki,
            h.vocab,
            200_000,
            opts.seed ^ 0xDD,
        );
        let mut log = RunLog::create(
            opts.out_dir.join("fig_native_convergence"),
            &format!("native_{}", mode.as_str()),
        )?;
        for _ in 0..steps {
            let stats =
                pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
            log.log(&stats)?;
        }
        let val = pipe.eval(4, |r| corpus.val_batch(h.b, h.n, r))?;
        let row = [
            mode.as_str().to_string(),
            format!("{:.6}", log.last_loss),
            format!("{val:.6}"),
            pipe.boundary_bytes().to_string(),
            format!(
                "{:.2}",
                crate::compress::wire_bytes(
                    Mode::Raw,
                    h.b,
                    h.n,
                    h.d,
                    h.k,
                    h.ratio
                ) as f64
                    / pipe.boundary_bytes() as f64
            ),
            format!("{:.3e}", pipe.subspace_leak()),
            format!("{:.1}", log.tps()),
        ];
        log.finish()?;
        Ok(row)
    })?;
    let mut csv = CsvWriter::create(
        opts.out_dir.join("fig_native_convergence.csv"),
        &[
            "mode",
            "final_train_loss",
            "val_loss",
            "boundary_wire_bytes",
            "compression_vs_raw",
            "subspace_leak",
            "tokens_per_sim_second",
        ],
    )?;
    for row in &rows {
        csv.row(row)?;
    }
    csv.finish()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// discrete-event swarm simulator — schedule × jitter grid, churn sweep
// ---------------------------------------------------------------------------

/// Event-simulator grid (DESIGN.md §9): for each (schedule, replicas,
/// bandwidth, jitter) cell, run the discrete-event swarm for a couple
/// of steps and report step timing; zero-jitter GPipe cells also emit
/// their relative deviation from the analytic `hybrid_makespan`
/// (the parity contract — expected ~0, gated at 1e-6 by the tests).
/// Artifact-free; cells are `RunSpec → Row` pool jobs, so the CSV is
/// byte-identical for any `--threads`.
pub fn sim_grid(opts: &ExpOpts) -> Result<()> {
    let hyper = if opts.fast { Hyper::small_sim() } else { Hyper::base_sim() };
    let schedules = [
        Schedule::Gpipe,
        Schedule::OneFOneB,
        Schedule::Interleaved { chunks: 2 },
    ];
    let bws_mbps: &[f64] =
        if opts.fast { &[80.0, 1000.0] } else { &[80.0, 300.0, 1000.0] };
    let jitters: &[f64] = if opts.fast { &[0.0, 0.2] } else { &[0.0, 0.1, 0.2] };
    let replicas: &[usize] = if opts.fast { &[1, 4] } else { &[1, 2, 4] };
    let mut cells: Vec<(Schedule, usize, f64, f64)> = Vec::new();
    for sched in schedules {
        for &r in replicas {
            for &bw in bws_mbps {
                for &jit in jitters {
                    cells.push((sched, r, bw, jit));
                }
            }
        }
    }
    let rows = par::try_map(
        opts.pool_threads(),
        &cells,
        |i, (sched, r, bw, jit)| {
            let mut spec = SwarmSpec::uniform(hyper.clone(), *r, bw * MBPS);
            spec.schedule = *sched;
            spec.link.jitter_frac = *jit;
            spec.ring_link.jitter_frac = *jit;
            spec.lat_jitter_frac = *jit;
            spec.steps = 2;
            spec.seed = par::cell_seed(opts.seed, i);
            let rep = simulate_swarm(&spec)?;
            // parity column: event engine vs closed-form on the cells
            // where the contract applies. Zero-jitter undisturbed steps
            // are identical, so the 2-step run's first step *is* the
            // single-step total — no extra simulation needed.
            let parity = if *sched == Schedule::Gpipe && *jit == 0.0 {
                let mut hs = HybridSimSpec::uniform(hyper.clone(), *r, bw * MBPS);
                hs.link.jitter_frac = 0.0;
                hs.ring_link.jitter_frac = 0.0;
                hs.seed = spec.seed;
                let hyb = simulate_hybrid_step(&hs);
                let rel = (rep.step_seconds[0] - hyb.makespan.total).abs()
                    / hyb.makespan.total.max(1e-12);
                format!("{rel:.3e}")
            } else {
                String::new()
            };
            Ok([
                sched.as_str().to_string(),
                r.to_string(),
                format!("{bw}"),
                format!("{jit}"),
                format!("{:.6}", rep.mean_step()),
                format!("{:.6}", rep.compute_end),
                format!("{:.6}", rep.comm_end),
                format!("{:.6}", rep.tail),
                format!("{:.6}", rep.comm_ser),
                format!("{:.6}", rep.allreduce_busy),
                parity,
            ])
        },
    )?;
    let mut csv = CsvWriter::create(
        opts.out_dir.join("fig_sim_grid.csv"),
        &[
            "schedule",
            "replicas",
            "bandwidth_mbps",
            "jitter",
            "mean_step_seconds",
            "compute_end_seconds",
            "comm_end_seconds",
            "tail_seconds",
            "pipeline_comm_ser_seconds",
            "allreduce_busy_seconds",
            "parity_rel_vs_analytic",
        ],
    )?;
    for row in &rows {
        csv.row(row)?;
    }
    csv.finish()?;
    Ok(())
}

/// Churn sweep (DESIGN.md §9): mean step time of the swarm under
/// increasing Poisson churn rates, subspace vs raw wire pricing, at
/// 80 Mbps. Because churn is a rate per simulated *second*, protocols
/// with slower steps absorb more churn per step — the degradation gap
/// `examples/churn_swarm.rs` asserts. Artifact-free pool jobs;
/// byte-identical CSVs at any `--threads`.
pub fn churn_sweep(opts: &ExpOpts) -> Result<()> {
    let hyper = if opts.fast { Hyper::small_sim() } else { Hyper::base_sim() };
    let steps = if opts.fast { 4 } else { 8 };
    let rates: &[f64] =
        if opts.fast { &[0.0, 0.3] } else { &[0.0, 0.1, 0.3, 1.0] };
    let modes = [Mode::Subspace, Mode::Raw];
    let mut cells: Vec<(Mode, f64)> = Vec::new();
    for mode in modes {
        for &rate in rates {
            cells.push((mode, rate));
        }
    }
    let rows =
        par::try_map(opts.pool_threads(), &cells, |i, (mode, rate)| {
            let mut spec = SwarmSpec::uniform(hyper.clone(), 4, 80.0 * MBPS);
            spec.mode = *mode;
            spec.dp_mode = *mode;
            spec.lat_jitter_frac = 0.1;
            spec.steps = steps;
            spec.seed = par::cell_seed(opts.seed, i);
            if *rate > 0.0 {
                spec.churn = ChurnSpec::Poisson {
                    rate_per_s: *rate,
                    downtime_s: 0.5,
                };
            }
            let rep = simulate_swarm(&spec)?;
            Ok((
                mode.as_str().to_string(),
                *rate,
                rep.mean_step(),
                rep.total,
                rep.leaves,
                rep.rejoins,
                rep.allreduce_restarts,
                rep.sync_seconds,
                rep.min_active,
            ))
        })?;
    let mut csv = CsvWriter::create(
        opts.out_dir.join("fig_churn_sweep.csv"),
        &[
            "mode",
            "churn_rate_per_s",
            "mean_step_seconds",
            "total_seconds",
            "leaves",
            "rejoins",
            "allreduce_restarts",
            "sync_seconds",
            "min_active",
            "degrade_vs_no_churn",
        ],
    )?;
    for (mode, rate, mean_step, total, leaves, rejoins, restarts, sync, min_active) in
        &rows
    {
        // the rate-0 row of the same mode is the degradation baseline
        let base = rows
            .iter()
            .find(|r| r.0 == *mode && r.1 == 0.0)
            .map(|r| r.2)
            .unwrap_or(*mean_step);
        csv.row(&[
            mode.clone(),
            format!("{rate}"),
            format!("{mean_step:.6}"),
            format!("{total:.6}"),
            leaves.to_string(),
            rejoins.to_string(),
            restarts.to_string(),
            format!("{sync:.6}"),
            min_active.to_string(),
            format!("{:.3}", mean_step / base.max(1e-12)),
        ])?;
    }
    csv.finish()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Theorem B.1 — error accumulation of lossy compression with depth
// ---------------------------------------------------------------------------

/// Theorem B.1: boundary-error accumulation of lossy schemes with depth.
pub fn error_accumulation(opts: &ExpOpts) -> Result<()> {
    let m = opts.manifest()?;
    let config = "tiny";
    let cm = m.config(config)?;
    let h = cm.hyper.clone();
    let mut rt = crate::runtime::Runtime::new(&m, config)?;
    let mut rng = Rng::new(opts.seed);
    let global = crate::stage::GlobalState::init(cm, &mut rng);
    let st = crate::stage::StageState::init(
        cm, 1, Mode::Raw, &global, &mut rng)?;
    let corpus = Corpus::synthetic(CorpusKind::Wiki, h.vocab, 50_000, 11);
    let (tok, _) = corpus.train_batch(h.b, h.n, &mut rng);

    // embed once through the raw first stage to get a realistic activation
    let mut args: Vec<crate::tensor::Value> = crate::stage::StageState::init(
        cm, 0, Mode::Raw, &global, &mut rng)?
        .params
        .into_iter()
        .map(crate::tensor::Value::F32)
        .collect();
    args.push(crate::tensor::Value::I32(tok));
    let x0 = rt.execute("raw/first_fwd", &args)?[0].as_f32().clone();

    let depths = 12usize;
    let mut csv = CsvWriter::create(
        opts.out_dir.join("thmB1_error_accumulation.csv"),
        &["depth", "mode", "relative_error"],
    )?;
    for mode in [Mode::TopK, Mode::Quant, Mode::PowerLR] {
        let mut x_clean = x0.clone();
        let mut x_lossy = x0.clone();
        for depth in 1..=depths {
            let stage_params: Vec<crate::tensor::Value> =
                st.params.iter().cloned().map(crate::tensor::Value::F32).collect();
            let mut a = stage_params.clone();
            a.push(crate::tensor::Value::F32(x_clean.clone()));
            x_clean = rt.execute("raw/mid_fwd", &a)?[0].as_f32().clone();
            let mut b = stage_params;
            b.push(crate::tensor::Value::F32(x_lossy.clone()));
            x_lossy = rt
                .execute(&format!("{}/mid_fwd", mode.as_str()), &b)?[0]
                .as_f32()
                .clone();
            let num: f64 = x_clean
                .data
                .iter()
                .zip(&x_lossy.data)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            let den = x_clean
                .data
                .iter()
                .map(|a| (*a as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            csv.row(&[
                depth.to_string(),
                mode.as_str().to_string(),
                format!("{:.6}", num / den),
            ])?;
        }
    }
    // the subspace scheme: zero boundary error at any depth by Eq. 7 —
    // emit explicitly for the figure
    for depth in 1..=depths {
        csv.row(&[depth.to_string(), "subspace".into(), "0.0".into()])?;
    }
    csv.finish()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// transport-report: measured vs predicted distributed step wall-clock
// ---------------------------------------------------------------------------

/// Measured-vs-predicted wall-clock for the distributed transport
/// (DESIGN.md §11): run the tiny preset distributed over both transport
/// backends and compare the measured mean step wall-clock against the
/// cost model's prediction. The predictor is a single-process native
/// run priced with `TimeModel::Measured` (real per-stage host compute)
/// over a loopback-class `LinkSpec`, composed once by the analytic
/// GPipe recurrence and once by the discrete-event engine — the same
/// measured-vs-predicted discipline `sim-grid` applies to virtual time,
/// applied to real wall-clock. Emits `fig_transport_report.csv`; no
/// thresholds are asserted here (absolute wall-clock is
/// machine-dependent), the smoke example checks structure instead.
pub fn transport_report(opts: &ExpOpts) -> Result<()> {
    use crate::netsim::GBPS;
    use crate::nn::{NativePipeline, Optim};
    use crate::transport::{run_local, TransportKind, WorkerSpec};

    let steps = opts.steps_or(30, 8);
    let h = Hyper::tiny_native();
    let mk_cfg = |tm: TimeModel, event_sim: bool| PipelineConfig {
        mode: Mode::Subspace,
        microbatches: 2,
        grassmann_interval: 0,
        lr: 1e-2,
        warmup_steps: (steps / 20).max(3),
        total_steps: steps,
        time_model: tm,
        seed: opts.seed,
        event_sim,
        ..Default::default()
    };
    let spec = WorkerSpec {
        h: h.clone(),
        cfg: mk_cfg(TimeModel::default_analytic(), false),
        optim: Optim::AdamW,
        steps,
        corpus_kind: CorpusKind::Wiki,
        corpus_tokens: 100_000,
    };

    // predictions: per-stage compute measured in this process, boundary
    // transfers priced on a loopback-class link, composed by the gpipe
    // recurrence and by the event engine (identical for gpipe by the
    // sim parity contract — both are emitted to show it holds on
    // measured costs too)
    let loopback = LinkSpec {
        bandwidth_bps: 10.0 * GBPS,
        latency_s: 50e-6,
        jitter_frac: 0.0,
    };
    let mut predicted = [0.0f64; 2];
    for (i, event_sim) in [false, true].into_iter().enumerate() {
        let mut rng = Rng::new(opts.seed);
        let topo = Topology::uniform(h.stages, loopback, &mut rng);
        let mut pipe = NativePipeline::new(
            h.clone(),
            topo,
            mk_cfg(TimeModel::Measured, event_sim),
            Optim::AdamW,
        )?;
        let corpus = spec.corpus();
        let mut sum = 0.0;
        for _ in 0..steps {
            sum += pipe
                .train_step(|r| corpus.train_batch(h.b, h.n, r))?
                .sim_seconds;
        }
        predicted[i] = sum / steps as f64;
    }

    let mut csv = CsvWriter::create(
        opts.out_dir.join("fig_transport_report.csv"),
        &[
            "transport",
            "steps",
            "measured_step_s",
            "predicted_gpipe_s",
            "predicted_event_s",
            "measured_over_predicted",
        ],
    )?;
    for kind in [TransportKind::Channel, TransportKind::Tcp] {
        let rep = run_local(&spec, kind)?;
        let measured = rep.mean_step_seconds();
        csv.row(&[
            kind.as_str().into(),
            steps.to_string(),
            format!("{measured:.6}"),
            format!("{:.6}", predicted[0]),
            format!("{:.6}", predicted[1]),
            format!("{:.3}", measured / predicted[0].max(1e-12)),
        ])?;
        eprintln!(
            "[transport-report] {}: measured {measured:.4}s/step vs \
             predicted {:.4}s (gpipe) / {:.4}s (event)",
            kind.as_str(),
            predicted[0],
            predicted[1]
        );
    }
    csv.finish()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// dp-real: ring vs gossip step wall on a real R×P grid with a straggler
// ---------------------------------------------------------------------------

/// Real data parallelism under a straggler (DESIGN.md §14): train the
/// tiny preset on a 3×P worker grid over the channel backend, once with
/// the ring all-reduce and once with gossip, while replica 1 sleeps an
/// extra `straggle` seconds before every gradient exchange. The ring is
/// a per-step barrier, so *every* replica's predicted step wall is
/// `base + straggle`; gossip couples a healthy replica to the straggler
/// only on the steps the seeded schedule pairs them, so its predicted
/// wall is `base + straggle·frac(r)` with `frac` read off the exact
/// deterministic [`crate::transport::gossip_partner`] schedule. `base`
/// is the measured single-replica (R = 1) step wall of the identical
/// spec. Emits `fig_dp_real.csv` (one row per reduce × replica,
/// measured vs predicted); no thresholds are asserted (absolute
/// wall-clock is machine-dependent), the CI smoke leg checks structure.
pub fn dp_real(opts: &ExpOpts) -> Result<()> {
    use crate::transport::{
        gossip_partner, launch, Reduce, TrainSpec, TransportKind,
    };

    let steps = opts.steps_or(12, 6);
    let replicas = 3usize;
    let straggler = 1usize;
    let straggle_s = 0.06f64;
    let h = Hyper::tiny_native();
    let mk_spec = |r: usize, reduce: Reduce| -> Result<TrainSpec> {
        TrainSpec::builder(h.clone())
            .mode(Mode::Subspace)
            .steps(steps)
            .microbatches(2)
            .seed(opts.seed)
            .lr(1e-2)
            .warmup(3)
            .grassmann(0)
            .corpus(CorpusKind::Wiki, 60_000)
            .replicas(r)
            .dp_mode(Mode::Subspace)
            .reduce(reduce)
            .build()
    };

    // base: the same chain without a dp axis, measured in this process
    let base_spec = mk_spec(1, Reduce::None)?;
    let base_rep =
        launch(&base_spec.topology(TransportKind::Channel), &base_spec)?;
    let base = base_rep.mean_step_seconds();

    let mut csv = CsvWriter::create(
        opts.out_dir.join("fig_dp_real.csv"),
        &[
            "reduce",
            "replica",
            "role",
            "steps",
            "partner_frac",
            "measured_step_s",
            "predicted_step_s",
            "measured_over_predicted",
            "dp_payload_bytes",
        ],
    )?;
    for reduce in [Reduce::Ring, Reduce::Gossip { degree: 1 }] {
        let spec = mk_spec(replicas, reduce)?;
        let mut topo = spec.topology(TransportKind::Channel);
        topo.straggle = Some((straggler, straggle_s));
        let rep = launch(&topo, &spec)?;
        for r in 0..replicas {
            // fraction of steps replica r waits on the straggler
            let frac = match reduce {
                Reduce::Ring => 1.0,
                _ if r == straggler => 1.0,
                _ => {
                    let paired = (0..steps as u64)
                        .filter(|&s| {
                            gossip_partner(opts.seed, s, replicas, r)
                                == Some(straggler)
                        })
                        .count();
                    paired as f64 / steps as f64
                }
            };
            let secs = &rep.replica_step_seconds[r];
            let measured =
                secs.iter().sum::<f64>() / secs.len().max(1) as f64;
            let predicted = base + straggle_s * frac;
            csv.row(&[
                reduce.label().to_string(),
                r.to_string(),
                if r == straggler { "straggler" } else { "healthy" }
                    .into(),
                steps.to_string(),
                format!("{frac:.3}"),
                format!("{measured:.6}"),
                format!("{predicted:.6}"),
                format!("{:.3}", measured / predicted.max(1e-12)),
                rep.dp_payload_bytes.to_string(),
            ])?;
        }
        let healthy: Vec<f64> = (0..replicas)
            .filter(|&r| r != straggler)
            .map(|r| {
                let s = &rep.replica_step_seconds[r];
                s.iter().sum::<f64>() / s.len().max(1) as f64
            })
            .collect();
        eprintln!(
            "[dp-real] {}: healthy mean {:.4}s/step (base {base:.4}s, \
             straggler +{straggle_s:.3}s, dp payload {} B)",
            reduce.label(),
            healthy.iter().sum::<f64>() / healthy.len() as f64,
            rep.dp_payload_bytes,
        );
    }
    csv.finish()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// trace-diff: recorded spans replayed against the event engine
// ---------------------------------------------------------------------------

/// Record a small distributed channel run with the span tracer, then
/// replay the recorded per-(stage, microbatch) compute spans and frame
/// sends through the §9 event engine and report per-task placement
/// error (DESIGN.md §15). The engine is fed the *measured* durations
/// from the trace, so the comparison isolates the scheduler's task
/// placement from machine speed — what remains is host-side queueing
/// and thread wakeup latency the engine does not model. Emits
/// `fig_trace_diff.csv` (one row per task) and prints the summary; no
/// hard threshold is asserted here (wall-clock noise is
/// machine-dependent), the CI smoke job applies its ceiling to the
/// printed mean.
pub fn trace_diff(opts: &ExpOpts) -> Result<()> {
    use crate::nn::Optim;
    use crate::obs::diff::diff_trace;
    use crate::obs::trace::{Clock, TraceSession};
    use crate::transport::{run_local, TransportKind, WorkerSpec};

    let steps = opts.steps_or(8, 4);
    let h = Hyper::tiny_native();
    let cfg = PipelineConfig {
        mode: Mode::Subspace,
        microbatches: 4,
        grassmann_interval: 0,
        lr: 1e-2,
        warmup_steps: 3,
        total_steps: steps,
        seed: opts.seed,
        ..Default::default()
    };
    let spec = WorkerSpec {
        h: h.clone(),
        cfg,
        optim: Optim::AdamW,
        steps,
        corpus_kind: CorpusKind::Wiki,
        corpus_tokens: 100_000,
    };
    let session = TraceSession::start(Clock::Host);
    let rep = run_local(&spec, TransportKind::Channel)?;
    let trace = session.stop();
    if rep.losses.len() != steps {
        bail!("traced run logged {} of {steps} steps", rep.losses.len());
    }
    let report = diff_trace(&trace, Schedule::Gpipe)?;
    if report.rows.is_empty() {
        bail!("trace-diff produced no comparable tasks");
    }
    if !report.max_rel_err.is_finite() {
        bail!("trace-diff relative error is not finite");
    }
    let mut csv = CsvWriter::create(
        opts.out_dir.join("fig_trace_diff.csv"),
        &[
            "step",
            "stage",
            "mb",
            "class",
            "measured_start_s",
            "measured_end_s",
            "predicted_start_s",
            "predicted_end_s",
            "rel_err",
        ],
    )?;
    for r in &report.rows {
        csv.row(&[
            r.step.to_string(),
            r.stage.to_string(),
            r.mb.to_string(),
            r.class.to_string(),
            format!("{:.6}", r.measured_start_s),
            format!("{:.6}", r.measured_end_s),
            format!("{:.6}", r.predicted_start_s),
            format!("{:.6}", r.predicted_end_s),
            format!("{:.4}", r.rel_err),
        ])?;
    }
    csv.finish()?;
    eprintln!("[trace-diff] {}", report.summary());
    Ok(())
}

// ---------------------------------------------------------------------------
// serve-report: decode serving throughput / latency vs bandwidth × batch
// ---------------------------------------------------------------------------

/// Decode-serving report (DESIGN.md §16): tokens/sec and latency tails
/// across a bandwidth × max-batch grid, with the §9-style serving
/// simulator's predictions held against measured runs. Calibration and
/// comparison follow `transport-report` / `trace-diff` discipline:
///
/// 1. one measured single-process decode run fits an effective device
///    rate (predicted FLOPs over measured wall — machine speed out of
///    the loop);
/// 2. every grid cell's throughput and p50/p99 latency is *predicted*
///    by [`predict_serve`], which replays the runtime's replicated
///    batcher verbatim and prices frames on the cell's [`LinkSpec`];
/// 3. per max-batch, one measured TCP `serve-infer` run fills the
///    loopback row's measured columns and the
///    `measured_over_predicted` ratio.
///
/// Emits `fig_serve_report.csv`; no threshold is asserted here
/// (wall-clock is machine-dependent), the CI `serve-smoke` leg checks
/// structure and uploads the figure.
///
/// [`predict_serve`]: crate::sim::predict_serve
pub fn serve_report(opts: &ExpOpts) -> Result<()> {
    use crate::netsim::GBPS;
    use crate::sim::predict_serve;
    use crate::transport::{
        run_serve_local, serve_infer, ServeSpec, TrafficSpec,
        TransportKind,
    };

    let budget = opts.steps_or(600, 300);
    let h = Hyper::tiny_native();
    let traffic = TrafficSpec {
        sessions: if opts.fast { 4 } else { 6 },
        mean_gap: 1.5,
        prompt: (4, 8),
        gen: (4, 6),
    };
    let mk_spec = |max_batch: usize| -> Result<ServeSpec> {
        ServeSpec::builder(h.clone())
            .mode(Mode::Subspace)
            .steps(budget)
            .seed(opts.seed)
            .corpus(CorpusKind::Wiki, 100_000)
            .traffic(traffic.clone())
            .max_batch(max_batch)
            .build()
    };
    let loopback = LinkSpec {
        bandwidth_bps: 10.0 * GBPS,
        latency_s: 50e-6,
        jitter_frac: 0.0,
    };
    let grid_links: &[(&str, LinkSpec)] = &[
        ("loopback", loopback),
        ("16gbps", LinkSpec::centralized_16g()),
        ("80mbps", LinkSpec::internet_80m()),
    ];
    let batches: &[usize] = &[1, 2, 4];

    // calibrate: predicted FLOPs of the widest-batch schedule over its
    // measured single-process wall
    let cal_spec = mk_spec(*batches.last().unwrap())?;
    let flops: f64 = predict_serve(&cal_spec, &loopback, 1.0)?
        .steps
        .iter()
        .map(|s| s.compute_s)
        .sum();
    let cal_wall = run_serve_local(&cal_spec)?.wall_seconds();
    if !(cal_wall > 0.0) {
        bail!("serve-report calibration run measured no wall time");
    }
    let device_flops = flops / cal_wall;

    let mut csv = CsvWriter::create(
        opts.out_dir.join("fig_serve_report.csv"),
        &[
            "bandwidth",
            "max_batch",
            "steps",
            "sessions",
            "predicted_tokens_per_sec",
            "predicted_p50_s",
            "predicted_p99_s",
            "predicted_step_s",
            "measured_tokens_per_sec",
            "measured_step_s",
            "measured_over_predicted",
        ],
    )?;
    let mut rows = 0usize;
    for &max_batch in batches {
        let spec = mk_spec(max_batch)?;
        // measured leg: the real staged decode over TCP loopback
        let meas = serve_infer(&spec, TransportKind::Tcp)?;
        for (bw, link) in grid_links {
            let pred = predict_serve(&spec, link, device_flops)?;
            if pred.steps.is_empty() {
                bail!("serve-report predicted an empty schedule");
            }
            if !pred.mean_step_seconds().is_finite() {
                bail!("serve-report predicted step wall is not finite");
            }
            let measured_here = *bw == "loopback";
            let (m_tps, m_step, ratio) = if measured_here {
                if meas.steps != pred.steps.len() as u64 {
                    bail!(
                        "serving simulator executed {} steps but the \
                         measured run executed {} — schedule replay \
                         diverged",
                        pred.steps.len(),
                        meas.steps
                    );
                }
                let m = meas.mean_step_seconds();
                (
                    format!("{:.1}", meas.tokens_per_sec()),
                    format!("{m:.6}"),
                    format!(
                        "{:.3}",
                        m / pred.mean_step_seconds().max(1e-12)
                    ),
                )
            } else {
                (String::new(), String::new(), String::new())
            };
            csv.row(&[
                (*bw).to_string(),
                max_batch.to_string(),
                pred.steps.len().to_string(),
                traffic.sessions.to_string(),
                format!("{:.1}", pred.tokens_per_sec()),
                format!("{:.6}", pred.latency_percentile(50.0)),
                format!("{:.6}", pred.latency_percentile(99.0)),
                format!("{:.6}", pred.mean_step_seconds()),
                m_tps,
                m_step,
                ratio,
            ])?;
            rows += 1;
        }
        eprintln!(
            "[serve-report] batch {max_batch}: measured {:.1} tok/s \
             over TCP ({} steps, p99 {:.4}s)",
            meas.tokens_per_sec(),
            meas.steps,
            meas.latency_percentile(99.0),
        );
    }
    if rows == 0 {
        bail!("serve-report emitted no rows");
    }
    csv.finish()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// dispatcher
// ---------------------------------------------------------------------------

/// Every experiment name `run` accepts (besides the `all` meta-driver).
pub const ALL: &[&str] = &[
    "dp-grid",
    "sim-grid",
    "churn-sweep",
    "convergence-native",
    "rank-collapse",
    "checkpoint-ranks",
    "convergence-bandwidth",
    "depth-sweep",
    "throughput-sweep",
    "global-regions",
    "lossy-comparison",
    "batch-sweep",
    "context-sweep",
    "grassmann-ablation",
    "embedding-ablation",
    "table1",
    "table2",
    "memory-seqlen",
    "memory-workers",
    "error-accumulation",
    "transport-report",
    "dp-real",
    "trace-diff",
    "serve-report",
];

/// Run one experiment driver by name (`"all"` runs the full suite).
pub fn run(name: &str, opts: &ExpOpts) -> Result<()> {
    std::fs::create_dir_all(&opts.out_dir)?;
    match name {
        "dp-grid" => dp_grid(opts),
        "sim-grid" => sim_grid(opts),
        "churn-sweep" => churn_sweep(opts),
        "convergence-native" => convergence_native(opts),
        "rank-collapse" => rank_collapse(opts, false),
        "rank-collapse-grads" => rank_collapse(opts, true),
        "checkpoint-ranks" => checkpoint_ranks(opts),
        "convergence-bandwidth" => convergence_bandwidth(opts),
        "depth-sweep" => depth_sweep(opts),
        "throughput-sweep" => throughput_sweep(opts),
        "global-regions" => global_regions(opts),
        "lossy-comparison" => lossy_comparison(opts),
        "batch-sweep" => batch_sweep(opts),
        "context-sweep" => context_sweep(opts),
        "grassmann-ablation" => grassmann_ablation(opts),
        "embedding-ablation" => embedding_ablation(opts),
        "table1" => table1(opts),
        "table2" => table2(opts),
        "memory-seqlen" => memory_seqlen(opts),
        "memory-workers" => memory_workers(opts),
        "error-accumulation" => error_accumulation(opts),
        "transport-report" => transport_report(opts),
        "dp-real" => dp_real(opts),
        "trace-diff" => trace_diff(opts),
        "serve-report" => serve_report(opts),
        "all" => {
            for e in ALL {
                eprintln!("=== exp {e} ===");
                run(e, opts)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?}; have {ALL:?}"),
    }
}

/// Resolve the results directory for a given base path.
pub fn out_dir_for(base: &Path) -> PathBuf {
    base.to_path_buf()
}
