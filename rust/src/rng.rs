//! Deterministic pseudo-random generation (no external crates).
//!
//! SplitMix64 seeds an xoshiro256++ state; Box–Muller provides normals;
//! a Zipf sampler powers the synthetic corpus. Every run is reproducible
//! from a single u64 seed.

/// Deterministic xoshiro256++ generator with normal/uniform helpers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Generator seeded via SplitMix64 expansion of `seed`.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (stage workers, data shards, links).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let (mut u1, u2) = (self.uniform(), self.uniform());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// N(mu, sigma²), truncated at a floor (bandwidth must stay positive).
    pub fn normal_clamped(&mut self, mu: f64, sigma: f64, floor: f64) -> f64 {
        (mu + sigma * self.normal()).max(floor)
    }

    /// `n` independent N(0, std²) samples as f32.
    pub fn normal_f32_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * std).collect()
    }
}

/// Zipf(s) sampler over [0, n) via precomputed CDF — token unigram skew
/// for the synthetic corpora.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Zipf(s) distribution over `[0, n)` with precomputed CDF.
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 1..=n {
            acc += 1.0 / (i as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Draw one index.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(7);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut rng = Rng::new(5);
        let z = Zipf::new(100, 1.1);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[50] * 3, "head not heavy: {:?}", &counts[..5]);
    }

    #[test]
    fn normal_clamped_respects_floor() {
        let mut rng = Rng::new(9);
        for _ in 0..1000 {
            assert!(rng.normal_clamped(1.0, 10.0, 0.25) >= 0.25);
        }
    }
}
