//! Run metrics: loss/throughput/wire curves → CSV files under results/.
//!
//! Every experiment harness (`protomodels exp …`) emits its figure/table
//! data through this module so the output format is uniform:
//! one CSV per curve family, `step` or `x` as the first column.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub struct CsvWriter {
    path: PathBuf,
    out: BufWriter<File>,
    cols: usize,
    rows: usize,
}

impl CsvWriter {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<CsvWriter> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(
            File::create(&path).with_context(|| format!("create {path:?}"))?,
        );
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { path, out, cols: header.len(), rows: 0 })
    }

    pub fn row(&mut self, vals: &[String]) -> Result<()> {
        debug_assert_eq!(vals.len(), self.cols, "{:?}", self.path);
        writeln!(self.out, "{}", vals.join(","))?;
        self.rows += 1;
        Ok(())
    }

    pub fn rowf(&mut self, vals: &[f64]) -> Result<()> {
        self.row(&vals.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }

    pub fn finish(mut self) -> Result<PathBuf> {
        self.out.flush()?;
        eprintln!("[metrics] wrote {} rows → {}", self.rows, self.path.display());
        Ok(self.path)
    }
}

/// A training-run log: one row per step.
pub struct RunLog {
    csv: CsvWriter,
    pub label: String,
    /// cumulative simulated seconds
    pub sim_time: f64,
    pub tokens: u64,
    pub bytes: u64,
    pub last_loss: f64,
}

impl RunLog {
    pub fn create(dir: impl AsRef<Path>, label: &str) -> Result<RunLog> {
        let csv = CsvWriter::create(
            dir.as_ref().join(format!("{label}.csv")),
            &[
                "step",
                "loss",
                "sim_seconds",
                "cum_sim_seconds",
                "wire_bytes",
                "cum_wire_bytes",
                "tokens_per_sim_second",
            ],
        )?;
        Ok(RunLog {
            csv,
            label: label.to_string(),
            sim_time: 0.0,
            tokens: 0,
            bytes: 0,
            last_loss: f64::NAN,
        })
    }

    pub fn log(&mut self, s: &crate::coordinator::StepStats) -> Result<()> {
        self.sim_time += s.sim_seconds;
        self.tokens += s.tokens as u64;
        self.bytes += s.wire_bytes;
        self.last_loss = s.loss;
        let tps = s.tokens as f64 / s.sim_seconds.max(1e-12);
        self.csv.row(&[
            s.step.to_string(),
            format!("{:.6}", s.loss),
            format!("{:.6}", s.sim_seconds),
            format!("{:.6}", self.sim_time),
            s.wire_bytes.to_string(),
            self.bytes.to_string(),
            format!("{tps:.2}"),
        ])
    }

    pub fn tps(&self) -> f64 {
        self.tokens as f64 / self.sim_time.max(1e-12)
    }

    pub fn finish(self) -> Result<PathBuf> {
        self.csv.finish()
    }
}

/// Perplexity from a mean cross-entropy loss.
pub fn perplexity(mean_ce: f64) -> f64 {
    mean_ce.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("protomodels_test_metrics");
        let mut w =
            CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        w.rowf(&[1.0, 2.5]).unwrap();
        w.rowf(&[3.0, -4.0]).unwrap();
        let p = w.finish().unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("a,b\n1,2.5\n"));
    }

    #[test]
    fn perplexity_of_zero_loss_is_one() {
        assert!((perplexity(0.0) - 1.0).abs() < 1e-12);
        assert!(perplexity(2.0) > 7.0);
    }
}
