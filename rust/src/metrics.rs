//! Run metrics: loss/throughput/wire curves → CSV files under results/.
//!
//! Every experiment harness (`protomodels exp …`) emits its figure/table
//! data through this module so the output format is uniform:
//! one CSV per curve family, `step` or `x` as the first column.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Buffered CSV emitter with a fixed header (one per curve family).
pub struct CsvWriter {
    path: PathBuf,
    out: BufWriter<File>,
    cols: usize,
    rows: usize,
}

impl CsvWriter {
    /// Create the file (and parent dirs) and write the header row.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<CsvWriter> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(
            File::create(&path).with_context(|| format!("create {path:?}"))?,
        );
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { path, out, cols: header.len(), rows: 0 })
    }

    /// Append one row (must match the header's column count).
    pub fn row(&mut self, vals: &[String]) -> Result<()> {
        debug_assert_eq!(vals.len(), self.cols, "{:?}", self.path);
        writeln!(self.out, "{}", vals.join(","))?;
        self.rows += 1;
        Ok(())
    }

    /// Append one row of floats.
    pub fn rowf(&mut self, vals: &[f64]) -> Result<()> {
        self.row(&vals.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }

    /// Flush and report the written path.
    pub fn finish(mut self) -> Result<PathBuf> {
        self.out.flush()?;
        eprintln!("[metrics] wrote {} rows → {}", self.rows, self.path.display());
        Ok(self.path)
    }
}

/// A training-run log: one row per step.
pub struct RunLog {
    csv: CsvWriter,
    /// run label (also the CSV file stem)
    pub label: String,
    /// cumulative simulated seconds
    pub sim_time: f64,
    /// cumulative tokens consumed
    pub tokens: u64,
    /// cumulative wire bytes
    pub bytes: u64,
    /// most recent step's training loss
    pub last_loss: f64,
}

impl RunLog {
    /// Create `dir/<label>.csv` with the standard curve columns.
    pub fn create(dir: impl AsRef<Path>, label: &str) -> Result<RunLog> {
        let csv = CsvWriter::create(
            dir.as_ref().join(format!("{label}.csv")),
            &[
                "step",
                "loss",
                "sim_seconds",
                "cum_sim_seconds",
                "wire_bytes",
                "cum_wire_bytes",
                "tokens_per_sim_second",
            ],
        )?;
        Ok(RunLog {
            csv,
            label: label.to_string(),
            sim_time: 0.0,
            tokens: 0,
            bytes: 0,
            last_loss: f64::NAN,
        })
    }

    /// Log one pipeline step.
    pub fn log(&mut self, s: &crate::coordinator::StepStats) -> Result<()> {
        self.log_parts(s.step, s.loss, s.sim_seconds, s.wire_bytes, s.tokens)
    }

    /// Log one step from raw parts — the shared path for pipeline and
    /// replicated (data-parallel) step statistics.
    pub fn log_parts(
        &mut self,
        step: u64,
        loss: f64,
        sim_seconds: f64,
        wire_bytes: u64,
        tokens: usize,
    ) -> Result<()> {
        self.sim_time += sim_seconds;
        self.tokens += tokens as u64;
        self.bytes += wire_bytes;
        self.last_loss = loss;
        let tps = tokens as f64 / sim_seconds.max(1e-12);
        self.csv.row(&[
            step.to_string(),
            format!("{loss:.6}"),
            format!("{sim_seconds:.6}"),
            format!("{:.6}", self.sim_time),
            wire_bytes.to_string(),
            self.bytes.to_string(),
            format!("{tps:.2}"),
        ])
    }

    /// Mean tokens per simulated second over the whole run.
    pub fn tps(&self) -> f64 {
        self.tokens as f64 / self.sim_time.max(1e-12)
    }

    /// Flush and close the CSV.
    pub fn finish(self) -> Result<PathBuf> {
        self.csv.finish()
    }
}

/// Perplexity from a mean cross-entropy loss.
pub fn perplexity(mean_ce: f64) -> f64 {
    mean_ce.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("protomodels_test_metrics");
        let mut w =
            CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        w.rowf(&[1.0, 2.5]).unwrap();
        w.rowf(&[3.0, -4.0]).unwrap();
        let p = w.finish().unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("a,b\n1,2.5\n"));
    }

    #[test]
    fn runlog_accumulates_parts() {
        let dir = std::env::temp_dir().join("protomodels_test_runlog");
        let mut log = RunLog::create(&dir, "t").unwrap();
        log.log_parts(1, 2.0, 0.5, 100, 64).unwrap();
        log.log_parts(2, 1.5, 0.5, 100, 64).unwrap();
        assert_eq!(log.tokens, 128);
        assert_eq!(log.bytes, 200);
        assert!((log.sim_time - 1.0).abs() < 1e-12);
        assert!((log.tps() - 128.0).abs() < 1e-9);
        assert_eq!(log.last_loss, 1.5);
        log.finish().unwrap();
    }

    #[test]
    fn perplexity_of_zero_loss_is_one() {
        assert!((perplexity(0.0) - 1.0).abs() < 1e-12);
        assert!(perplexity(2.0) > 7.0);
    }
}
