//! protomodels — Protocol Models reproduction (see DESIGN.md).
//!
//! Layer map (README.md has the full module table):
//! - L1 numerics come in two backends: AOT-compiled HLO artifacts
//!   (python/compile) executed through [`runtime`], and the native
//!   in-process autodiff backend [`nn`] (no artifacts, no PJRT);
//! - L2 model state lives in [`stage`] / [`manifest`];
//! - L3 systems — the [`coordinator`] pipeline, its replicated
//!   data-parallel layer ([`coordinator::replica`]), the [`netsim`]
//!   substrate, the [`timemodel`] virtual clock, the [`compress`]
//!   wire accounting, and the discrete-event swarm simulator ([`sim`]:
//!   jitter, churn, async schedules) — drive everything and are what
//!   the experiments in [`exp`] measure.

#![warn(missing_docs)]

pub mod bench;
pub mod cli;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod json;
pub mod linalg;
pub mod manifest;
pub mod memory;
pub mod metrics;
pub mod netsim;
pub mod nn;
pub mod obs;
pub mod par;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod stage;
pub mod tensor;
pub mod timemodel;
pub mod transport;
