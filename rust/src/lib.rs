//! protomodels — Protocol Models reproduction (see DESIGN.md).

pub mod compress;
pub mod json;
pub mod linalg;
pub mod manifest;
pub mod netsim;
pub mod rng;
pub mod runtime;
pub mod tensor;
pub mod coordinator;
pub mod data;
pub mod stage;
pub mod timemodel;
pub mod cli;
pub mod exp;
pub mod memory;
pub mod metrics;
pub mod bench;
