//! Minimal JSON parser + writer (the offline vendor set has no serde_json).
//!
//! Parses the artifact manifest and run-config files; writes experiment
//! result JSON. Supports the full JSON grammar except `\u` surrogate
//! pairs (not needed for our ASCII manifests).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
#[allow(missing_docs)] // variants mirror the JSON grammar
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing bytes are an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing JSON at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object member by key, erroring when absent.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    /// Object member by key, if present.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string payload, erroring on other kinds.
    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// The numeric payload, erroring on other kinds.
    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// The numeric payload truncated to usize.
    pub fn usize(&self) -> Result<usize> {
        Ok(self.num()? as usize)
    }

    /// The array payload, erroring on other kinds.
    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// The object payload, erroring on other kinds.
    pub fn obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// Serialize into `out` (compact form, sorted object keys).
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Serialize to a compact string.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            );
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // UTF-8 passthrough: collect continuation bytes
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let chunk = std::str::from_utf8(
                            &self.b[start..start + len],
                        )?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested() {
        let j = Json::parse(
            r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny"}, "d": true, "e": null}"#,
        )
        .unwrap();
        assert_eq!(j.get("a").unwrap().arr().unwrap()[2], Json::Num(-300.0));
        assert_eq!(j.get("b").unwrap().get("c").unwrap().str().unwrap(), "x\ny");
        assert_eq!(j.get("d").unwrap(), &Json::Bool(true));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2,{"y":"z"}],"w":false}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.str().unwrap(), "Aé");
    }
}
