//! Host-side dense linear algebra (no external crates).
//!
//! Used by: parameter initialization (orthonormal U, in-S projection of
//! constrained weights), stable-rank tracking (Figs. 1/7/16), Grassmann
//! sanity checks, and the analytic compression baselines in tests.
//!
//! The SVD is one-sided Jacobi — O(d³) but robust, and our matrices are
//! ≤ 2048 wide; it runs off the training hot path (metrics cadence only).

use crate::tensor::Tensor;

/// C = A(m×k) · B(k×n), row-major.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, ka) = a.dims2();
    let (kb, n) = b.dims2();
    assert_eq!(ka, kb, "matmul {:?} x {:?}", a.shape, b.shape);
    let mut c = vec![0.0f32; m * n];
    // ikj loop order: streams B rows, vectorizes the inner j loop
    for i in 0..m {
        let arow = &a.data[i * ka..(i + 1) * ka];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b.data[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
    Tensor::new(vec![m, n], c)
}

/// Aᵀ for a 2-D tensor.
pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = a.dims2();
    let mut t = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            t[j * m + i] = a.data[i * n + j];
        }
    }
    Tensor::new(vec![n, m], t)
}

/// Project the rows of W onto S = Col(U):  W ← W · U · Uᵀ.
pub fn project_rows(w: &Tensor, u: &Tensor) -> Tensor {
    let wu = matmul(w, u);
    matmul(&wu, &transpose(u))
}

/// Orthonormalize the columns of A in place via modified Gram–Schmidt.
/// Returns false if a column was (numerically) dependent.
pub fn orthonormalize_columns(a: &mut Tensor) -> bool {
    let (m, n) = a.dims2();
    let mut ok = true;
    for j in 0..n {
        // subtract projections on previous columns
        for p in 0..j {
            let mut dot = 0.0f64;
            for i in 0..m {
                dot += a.data[i * n + p] as f64 * a.data[i * n + j] as f64;
            }
            for i in 0..m {
                a.data[i * n + j] -= (dot as f32) * a.data[i * n + p];
            }
        }
        let mut norm = 0.0f64;
        for i in 0..m {
            norm += (a.data[i * n + j] as f64).powi(2);
        }
        let norm = norm.sqrt();
        if norm < 1e-10 {
            ok = false;
            continue;
        }
        for i in 0..m {
            a.data[i * n + j] /= norm as f32;
        }
    }
    ok
}

/// Random matrix with orthonormal columns — the initial U_k (Sec. 8.1:
/// "We initialize U_k with isotropic Gaussian noise" + retraction).
pub fn random_orthonormal(rows: usize, cols: usize, rng: &mut crate::rng::Rng) -> Tensor {
    loop {
        let mut a = Tensor::new(
            vec![rows, cols],
            rng.normal_f32_vec(rows * cols, 1.0),
        );
        if orthonormalize_columns(&mut a) {
            return a;
        }
    }
}

/// Singular values via one-sided Jacobi on AᵀA column pairs.
pub fn singular_values(a: &Tensor) -> Vec<f32> {
    let (m, n) = a.dims2();
    // work on the thinner side
    let work = if m < n { transpose(a) } else { a.clone() };
    let (rows, cols) = work.dims2();
    let mut v = work.data.clone(); // columns rotated in place
    let idx = |i: usize, j: usize| i * cols + j;

    let max_sweeps = 30;
    let eps = 1e-10f64;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..cols {
            for q in (p + 1)..cols {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..rows {
                    let vp = v[idx(i, p)] as f64;
                    let vq = v[idx(i, q)] as f64;
                    app += vp * vp;
                    aqq += vq * vq;
                    apq += vp * vq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                off += apq.abs();
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..rows {
                    let vp = v[idx(i, p)] as f64;
                    let vq = v[idx(i, q)] as f64;
                    v[idx(i, p)] = (c * vp - s * vq) as f32;
                    v[idx(i, q)] = (s * vp + c * vq) as f32;
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }
    let mut sv: Vec<f32> = (0..cols)
        .map(|j| {
            (0..rows)
                .map(|i| (v[idx(i, j)] as f64).powi(2))
                .sum::<f64>()
                .sqrt() as f32
        })
        .collect();
    sv.sort_by(|a, b| b.partial_cmp(a).unwrap());
    sv
}

/// Stable (effective) rank  Σσᵢ² / max σᵢ²  — the paper's rank metric
/// (Sec. 4.1, Figs. 1/7/16).
pub fn stable_rank(a: &Tensor) -> f64 {
    let sv = singular_values(a);
    let max_sq = sv.first().map(|s| (*s as f64).powi(2)).unwrap_or(0.0);
    if max_sq <= 0.0 {
        return 0.0;
    }
    sv.iter().map(|s| (*s as f64).powi(2)).sum::<f64>() / max_sq
}

/// ‖A − A·U·Uᵀ‖_F — how far A's rows are from S (the "leak" metric used
/// by closure tests and the Grassmann accumulator diagnostics).
pub fn out_of_subspace_norm(a: &Tensor, u: &Tensor) -> f64 {
    let proj = project_rows(a, u);
    a.data
        .iter()
        .zip(&proj.data)
        .map(|(x, p)| ((x - p) as f64).powi(2))
        .sum::<f64>()
        .sqrt()
}

/// Best rank-r approximation error (for the error-accumulation experiment):
/// returns A projected onto its top-r singular subspace via orthogonal
/// iteration (deterministic start).
pub fn low_rank_approx(a: &Tensor, r: usize, rng: &mut crate::rng::Rng) -> Tensor {
    let (_, n) = a.dims2();
    let r = r.min(n);
    // Q ← orth(Aᵀ·A·sketch) — one subspace iteration is enough for tests
    let sketch = Tensor::new(vec![n, r], rng.normal_f32_vec(n * r, 1.0));
    let at = transpose(a);
    let mut q = matmul(&at, &matmul(a, &sketch));
    if !orthonormalize_columns(&mut q) {
        orthonormalize_columns(&mut q);
    }
    // A ≈ (A·Q)·Qᵀ
    matmul(&matmul(a, &q), &transpose(&q))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randt(rng: &mut Rng, m: usize, n: usize) -> Tensor {
        Tensor::new(vec![m, n], rng.normal_f32_vec(m * n, 1.0))
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = randt(&mut rng, 5, 7);
        let mut eye = Tensor::zeros(&[7, 7]);
        for i in 0..7 {
            eye.data[i * 7 + i] = 1.0;
        }
        let c = matmul(&a, &eye);
        assert_eq!(c.data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(vec![2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(matmul(&a, &b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involutive() {
        let mut rng = Rng::new(2);
        let a = randt(&mut rng, 3, 8);
        assert_eq!(transpose(&transpose(&a)).data, a.data);
    }

    #[test]
    fn orthonormalize_gives_orthonormal_columns() {
        let mut rng = Rng::new(3);
        let mut a = randt(&mut rng, 32, 6);
        assert!(orthonormalize_columns(&mut a));
        let g = matmul(&transpose(&a), &a);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (g.at2(i, j) - want).abs() < 1e-4,
                    "gram[{i},{j}]={}",
                    g.at2(i, j)
                );
            }
        }
    }

    #[test]
    fn svd_matches_known_diagonal() {
        // diag(3, 2, 1) embedded in a 4x3
        let mut a = Tensor::zeros(&[4, 3]);
        a.data[0] = 3.0;
        a.data[4] = 2.0;
        a.data[8] = 1.0;
        let sv = singular_values(&a);
        assert!((sv[0] - 3.0).abs() < 1e-4);
        assert!((sv[1] - 2.0).abs() < 1e-4);
        assert!((sv[2] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn svd_frobenius_identity() {
        let mut rng = Rng::new(4);
        let a = randt(&mut rng, 20, 12);
        let sv = singular_values(&a);
        let fro2: f64 = a.data.iter().map(|x| (*x as f64).powi(2)).sum();
        let sv2: f64 = sv.iter().map(|s| (*s as f64).powi(2)).sum();
        assert!(
            (fro2 - sv2).abs() / fro2 < 1e-4,
            "fro²={fro2} Σσ²={sv2}"
        );
    }

    #[test]
    fn stable_rank_of_low_rank_matrix() {
        let mut rng = Rng::new(5);
        // rank-2 matrix: outer products
        let u = randt(&mut rng, 40, 2);
        let v = randt(&mut rng, 2, 30);
        let a = matmul(&u, &v);
        let sr = stable_rank(&a);
        assert!(sr < 2.5, "stable rank {sr} of a rank-2 matrix");
        // full-rank gaussian should have much higher stable rank
        // 40x30 gaussian: ‖A‖_F² ≈ 1200, σ_max ≈ √40+√30 → stable rank ≈ 8.6
        let g = randt(&mut rng, 40, 30);
        assert!(stable_rank(&g) > 6.0);
    }

    #[test]
    fn project_rows_idempotent() {
        let mut rng = Rng::new(6);
        let u = random_orthonormal(16, 4, &mut rng);
        let w = randt(&mut rng, 10, 16);
        let p1 = project_rows(&w, &u);
        let p2 = project_rows(&p1, &u);
        for (a, b) in p1.data.iter().zip(&p2.data) {
            assert!((a - b).abs() < 1e-4);
        }
        assert!(out_of_subspace_norm(&p1, &u) < 1e-3);
    }

    #[test]
    fn low_rank_approx_reduces_error_with_rank() {
        let mut rng = Rng::new(7);
        let a = randt(&mut rng, 24, 24);
        let e2 = {
            let ap = low_rank_approx(&a, 2, &mut rng);
            a.data.iter().zip(&ap.data).map(|(x, y)| (x - y).powi(2)).sum::<f32>()
        };
        let e16 = {
            let ap = low_rank_approx(&a, 16, &mut rng);
            a.data.iter().zip(&ap.data).map(|(x, y)| (x - y).powi(2)).sum::<f32>()
        };
        assert!(e16 < e2, "rank-16 err {e16} !< rank-2 err {e2}");
    }
}
