//! Host-side dense linear algebra (no external crates).
//!
//! Used by: parameter initialization (orthonormal U, in-S projection of
//! constrained weights), stable-rank tracking (Figs. 1/7/16), Grassmann
//! sanity checks, and the analytic compression baselines in tests.
//!
//! Kernel engineering (DESIGN.md §8): `matmul` is cache-tiled and
//! row-parallel over scoped threads, `transpose` is blocked, and
//! `project_rows` fuses the `·Uᵀ` half through [`matmul_nt`] so Uᵀ is
//! never materialized. All kernels keep the per-element accumulation
//! order of the naive reference, so results are **identical for any
//! thread count** — the determinism contract the parallel experiment
//! grids rely on.
//!
//! Rank metrics: the exact path is one-sided Jacobi ([`singular_values`],
//! O(d³) but robust); the metrics cadence uses the randomized
//! range-finder [`stable_rank_approx`] (O(d²r) block subspace iteration
//! with a tolerance-checked fallback to the exact path).

use crate::tensor::Tensor;

/// k-strip length of the matmul micro-kernel (elements of one A row
/// kept hot per pass).
const MM_TILE_K: usize = 64;
/// j-strip length of the matmul micro-kernel (one C-row segment — 1 KiB
/// of f32, resident in L1 across the k strip).
const MM_TILE_J: usize = 256;
/// Multiply-add count below which threading is not worth the spawns.
/// Tuned down from the original 2²¹ once the backward pass started
/// issuing many mid-sized products per step (the tape's dW/dX matmuls on
/// tiny/small presets): at 2¹⁸ multiply-adds a scoped spawn costs well
/// under 10% of the kernel body, and the determinism contract makes the
/// threshold value invisible to results.
const MM_PAR_MIN_WORK: usize = 1 << 18;
/// Edge length of the blocked-transpose tile (32² f32 = 4 KiB).
const TR_TILE: usize = 32;

/// C = A(m×k) · B(k×n), row-major. Cache-tiled; rows of C are
/// partitioned across scoped threads when the FLOP count warrants it
/// (each output row is produced by exactly one thread with a fixed
/// k-ascending accumulation order, so the result is bitwise independent
/// of the thread count).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, ka) = a.dims2();
    let (kb, n) = b.dims2();
    assert_eq!(ka, kb, "matmul {:?} x {:?}", a.shape, b.shape);
    let mut c = vec![0.0f32; m * n];
    par_rows(m, ka, n, &a.data, &b.data, &mut c, matmul_rows);
    Tensor::new(vec![m, n], c)
}

/// Shared row-parallel dispatch of the matmul-family kernels: partition
/// C's rows across scoped threads (when the multiply-add count warrants
/// it) and run `kernel` on each disjoint block. Each output row is
/// produced by exactly one thread running the same serial kernel, so
/// results are bitwise independent of the thread count.
fn par_rows(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    kernel: fn(&[f32], usize, &[f32], usize, &mut [f32]),
) {
    let work = m.saturating_mul(k).saturating_mul(n);
    let threads = if work >= MM_PAR_MIN_WORK {
        crate::par::kernel_threads().min(m.max(1))
    } else {
        1
    };
    if threads <= 1 {
        kernel(a, k, b, n, c);
        return;
    }
    let rows_per = (m + threads - 1) / threads;
    let c_chunk = rows_per * n;
    let a_chunk = rows_per * k;
    std::thread::scope(|scope| {
        for (ci, c_rows) in c.chunks_mut(c_chunk).enumerate() {
            let rows = c_rows.len() / n;
            let a_rows = &a[ci * a_chunk..ci * a_chunk + rows * k];
            scope.spawn(move || kernel(a_rows, k, b, n, c_rows));
        }
    });
}

/// Row-block micro-kernel: `c (rows×n) += a (rows×k) · b (k×n)` with
/// k/j tiling and a 4-deep k unroll. For each output element the k index
/// ascends exactly as in the naive ikj loop — the unroll keeps the four
/// partial adds as *sequential* statements, so tiling and unrolling
/// change nothing but locality: the C segment is loaded and stored once
/// per four k values instead of once per k value, and the four
/// independent B streams give the autovectorizer contiguous
/// unit-stride work.
fn matmul_rows(a: &[f32], k: usize, b: &[f32], n: usize, c: &mut [f32]) {
    if n == 0 || k == 0 {
        return;
    }
    let rows = c.len() / n;
    for i in 0..rows {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + MM_TILE_K).min(k);
            let mut j0 = 0;
            while j0 < n {
                let j1 = (j0 + MM_TILE_J).min(n);
                let cseg = &mut crow[j0..j1];
                let mut kk = k0;
                while kk + 4 <= k1 {
                    let (a0, a1, a2, a3) = (
                        arow[kk],
                        arow[kk + 1],
                        arow[kk + 2],
                        arow[kk + 3],
                    );
                    let b0 = &b[kk * n + j0..kk * n + j1];
                    let b1 = &b[(kk + 1) * n + j0..(kk + 1) * n + j1];
                    let b2 = &b[(kk + 2) * n + j0..(kk + 2) * n + j1];
                    let b3 = &b[(kk + 3) * n + j0..(kk + 3) * n + j1];
                    for ((((cv, v0), v1), v2), v3) in cseg
                        .iter_mut()
                        .zip(b0)
                        .zip(b1)
                        .zip(b2)
                        .zip(b3)
                    {
                        // sequential adds: the naive k-ascending order
                        let mut t = *cv;
                        t += a0 * v0;
                        t += a1 * v1;
                        t += a2 * v2;
                        t += a3 * v3;
                        *cv = t;
                    }
                    kk += 4;
                }
                while kk < k1 {
                    let aik = arow[kk];
                    let brow = &b[kk * n + j0..kk * n + j1];
                    for (cv, bv) in cseg.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                    kk += 1;
                }
                j0 = j1;
            }
            k0 = k1;
        }
    }
}

/// Naive ikj reference matmul — the accumulation-order ground truth the
/// tiled kernel is tested against (and the baseline `bench --json`
/// reports speedups over).
pub fn matmul_reference(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, ka) = a.dims2();
    let (kb, n) = b.dims2();
    assert_eq!(ka, kb, "matmul {:?} x {:?}", a.shape, b.shape);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a.data[i * ka..(i + 1) * ka];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            let brow = &b.data[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
    Tensor::new(vec![m, n], c)
}

/// C = A(m×k) · B(n×k)ᵀ without materializing Bᵀ: each output element is
/// a row-dot of two contiguous rows. Same per-element accumulation order
/// as `matmul(a, &transpose(b))`; row-parallel like [`matmul`].
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, ka) = a.dims2();
    let (n, kb) = b.dims2();
    assert_eq!(ka, kb, "matmul_nt {:?} x {:?}T", a.shape, b.shape);
    let mut c = vec![0.0f32; m * n];
    par_rows(m, ka, n, &a.data, &b.data, &mut c, matmul_nt_rows);
    Tensor::new(vec![m, n], c)
}

/// Row-block kernel of [`matmul_nt`]: `c[i][j] = a_row_i · b_row_j`,
/// register-blocked four output columns at a time. Each of the four
/// dots keeps its own accumulator running in k-ascending order —
/// bitwise the same per-element sum as the plain loop — but the four
/// independent chains break the one-add-per-cycle latency wall of a
/// single serial dot, and each A element is loaded once per four
/// outputs instead of once per output.
fn matmul_nt_rows(a: &[f32], k: usize, b: &[f32], n: usize, c: &mut [f32]) {
    if n == 0 {
        return;
    }
    let rows = c.len() / n;
    for i in 0..rows {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let b2 = &b[(j + 2) * k..(j + 3) * k];
            let b3 = &b[(j + 3) * k..(j + 4) * k];
            let (mut s0, mut s1, mut s2, mut s3) =
                (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for ((((av, v0), v1), v2), v3) in
                arow.iter().zip(b0).zip(b1).zip(b2).zip(b3)
            {
                s0 += av * v0;
                s1 += av * v1;
                s2 += av * v2;
                s3 += av * v3;
            }
            crow[j] = s0;
            crow[j + 1] = s1;
            crow[j + 2] = s2;
            crow[j + 3] = s3;
            j += 4;
        }
        while j < n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (av, bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            crow[j] = acc;
            j += 1;
        }
    }
}

/// C = A(m×k)ᵀ · B(m×n) without materializing Aᵀ — the backward-pass
/// weight-gradient kernel (dW = Xᵀ·dY) of the native autodiff backend.
/// Output rows are partitioned across scoped threads; every output
/// element accumulates over the shared m index in ascending order, so the
/// result is bitwise independent of the thread count, like [`matmul`].
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (_, ka) = a.dims2();
    let (_, n) = b.dims2();
    let mut c = Tensor::zeros(&[ka, n]);
    matmul_tn_acc(a, b, &mut c);
    c
}

/// C += A(m×k)ᵀ · B(m×n) — the accumulate-into form of [`matmul_tn`]
/// behind microbatch-fused weight gradients: calling it once per
/// microbatch in microbatch order, on one running accumulator, performs
/// *exactly* the sum a single [`matmul_tn`] over the row-concatenated
/// microbatches would (the kernel streams the shared m index in
/// ascending order into C, so per-call accumulation just resumes the
/// same stream). Threading and bitwise thread-stability are identical
/// to [`matmul_tn`], which is implemented as this over a zero C.
pub fn matmul_tn_acc(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (m, ka) = a.dims2();
    let (mb, n) = b.dims2();
    assert_eq!(m, mb, "matmul_tn {:?}T x {:?}", a.shape, b.shape);
    assert_eq!(
        c.shape,
        vec![ka, n],
        "matmul_tn_acc accumulator shape {:?}",
        c.shape
    );
    if m == 0 || ka == 0 || n == 0 {
        return;
    }
    let work = m.saturating_mul(ka).saturating_mul(n);
    let threads = if work >= MM_PAR_MIN_WORK {
        crate::par::kernel_threads().min(ka)
    } else {
        1
    };
    if threads <= 1 {
        matmul_tn_rows(&a.data, ka, &b.data, n, 0, &mut c.data);
        return;
    }
    let rows_per = (ka + threads - 1) / threads;
    std::thread::scope(|scope| {
        for (ci, c_rows) in c.data.chunks_mut(rows_per * n).enumerate() {
            let i0 = ci * rows_per;
            let (a, b) = (&a.data, &b.data);
            scope.spawn(move || matmul_tn_rows(a, ka, b, n, i0, c_rows));
        }
    });
}

/// Row-block kernel of [`matmul_tn`]: output rows `i0 ..` of C = Aᵀ·B.
/// The m index ascends for every output element (one pass over A and B
/// per row block, streaming B rows), fixing the accumulation order.
///
/// Exact zeros in A are skipped — the ReLU-sparsity fast path for the
/// dW = h₁ᵀ·dY backward matmul, where half of h₁ is zero. For finite
/// inputs this is bitwise identical to the dense composition; the one
/// documented divergence is that a zero A element contributes nothing
/// even against a non-finite B element (0·NaN would poison the dense
/// result), so a NaN-diverged run surfaces through the loss and the
/// other gradient paths rather than through every dW row.
fn matmul_tn_rows(
    a: &[f32],
    ka: usize,
    b: &[f32],
    n: usize,
    i0: usize,
    c: &mut [f32],
) {
    let rows = c.len() / n;
    let m = a.len() / ka;
    for mm in 0..m {
        let arow = &a[mm * ka + i0..mm * ka + i0 + rows];
        let brow = &b[mm * n..(mm + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Aᵀ for a 2-D tensor, via cache-blocked tiles (both the read and the
/// write stream stay within a TLB-friendly window).
pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = a.dims2();
    let mut t = vec![0.0f32; m * n];
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + TR_TILE).min(m);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + TR_TILE).min(n);
            for i in i0..i1 {
                for j in j0..j1 {
                    t[j * m + i] = a.data[i * n + j];
                }
            }
            j0 = j1;
        }
        i0 = i1;
    }
    Tensor::new(vec![n, m], t)
}

/// Project the rows of W onto S = Col(U):  W ← (W·U)·Uᵀ. Fused: the
/// second product reads U's rows directly ([`matmul_nt`]) — neither
/// U·Uᵀ (d×d) nor Uᵀ is ever materialized.
pub fn project_rows(w: &Tensor, u: &Tensor) -> Tensor {
    let wu = matmul(w, u);
    matmul_nt(&wu, u)
}

/// Orthonormalize the columns of A in place via modified Gram–Schmidt.
/// Returns false if a column was (numerically) dependent.
///
/// Dependency is judged *relative* to the column's pre-projection norm
/// and dependent columns are **zeroed**, not normalized: an f32 MGS
/// residual of a dependent column is pure rounding noise (~1e-7
/// relative), and normalizing it manufactures a unit vector with O(0.1)
/// overlap against the earlier columns — which silently breaks every
/// downstream Q·Qᵀ projection and Gram bound (the pre-fix behavior, and
/// the root cause of the `low_rank_approx` rank-deficient bug).
pub fn orthonormalize_columns(a: &mut Tensor) -> bool {
    let (m, n) = a.dims2();
    let mut ok = true;
    for j in 0..n {
        let mut norm0 = 0.0f64;
        for i in 0..m {
            norm0 += (a.data[i * n + j] as f64).powi(2);
        }
        let norm0 = norm0.sqrt();
        // subtract projections on previous columns
        for p in 0..j {
            let mut dot = 0.0f64;
            for i in 0..m {
                dot += a.data[i * n + p] as f64 * a.data[i * n + j] as f64;
            }
            for i in 0..m {
                a.data[i * n + j] -= (dot as f32) * a.data[i * n + p];
            }
        }
        let mut norm = 0.0f64;
        for i in 0..m {
            norm += (a.data[i * n + j] as f64).powi(2);
        }
        let norm = norm.sqrt();
        if norm < (1e-6 * norm0).max(1e-10) {
            for i in 0..m {
                a.data[i * n + j] = 0.0;
            }
            ok = false;
            continue;
        }
        for i in 0..m {
            a.data[i * n + j] /= norm as f32;
        }
    }
    ok
}

/// Random matrix with orthonormal columns — the initial U_k (Sec. 8.1:
/// "We initialize U_k with isotropic Gaussian noise" + retraction).
pub fn random_orthonormal(rows: usize, cols: usize, rng: &mut crate::rng::Rng) -> Tensor {
    loop {
        let mut a = Tensor::new(
            vec![rows, cols],
            rng.normal_f32_vec(rows * cols, 1.0),
        );
        if orthonormalize_columns(&mut a) {
            return a;
        }
    }
}

/// Singular values via one-sided Jacobi on AᵀA column pairs.
pub fn singular_values(a: &Tensor) -> Vec<f32> {
    let (m, n) = a.dims2();
    // work on the thinner side
    let work = if m < n { transpose(a) } else { a.clone() };
    let (rows, cols) = work.dims2();
    let mut v = work.data.clone(); // columns rotated in place
    let idx = |i: usize, j: usize| i * cols + j;

    let max_sweeps = 30;
    let eps = 1e-10f64;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..cols {
            for q in (p + 1)..cols {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..rows {
                    let vp = v[idx(i, p)] as f64;
                    let vq = v[idx(i, q)] as f64;
                    app += vp * vp;
                    aqq += vq * vq;
                    apq += vp * vq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                off += apq.abs();
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..rows {
                    let vp = v[idx(i, p)] as f64;
                    let vq = v[idx(i, q)] as f64;
                    v[idx(i, p)] = (c * vp - s * vq) as f32;
                    v[idx(i, q)] = (s * vp + c * vq) as f32;
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }
    let mut sv: Vec<f32> = (0..cols)
        .map(|j| {
            (0..rows)
                .map(|i| (v[idx(i, j)] as f64).powi(2))
                .sum::<f64>()
                .sqrt() as f32
        })
        .collect();
    sv.sort_by(|a, b| b.partial_cmp(a).unwrap());
    sv
}

/// Stable (effective) rank  Σσᵢ² / max σᵢ²  — the paper's rank metric
/// (Sec. 4.1, Figs. 1/7/16). Exact: full one-sided Jacobi, O(d³).
pub fn stable_rank(a: &Tensor) -> f64 {
    let sv = singular_values(a);
    let max_sq = sv.first().map(|s| (*s as f64).powi(2)).unwrap_or(0.0);
    if max_sq <= 0.0 {
        return 0.0;
    }
    sv.iter().map(|s| (*s as f64).powi(2)).sum::<f64>() / max_sq
}

/// Default sketch width of [`stable_rank_approx`] (block size of the
/// subspace iteration — wide enough to capture near-degenerate top
/// singular values of soft-edge spectra).
pub const STABLE_RANK_SKETCH: usize = 8;
/// Power-iteration cap of [`stable_rank_approx`]; exceeded → exact
/// fallback.
const STABLE_RANK_MAX_ITERS: usize = 40;
/// Relative σ²_max convergence tolerance of [`stable_rank_approx`].
const STABLE_RANK_REL_TOL: f64 = 1e-5;

/// Randomized stable rank:  ‖A‖_F² / σ̂²_max  with σ̂_max from an
/// `r`-dimensional block subspace iteration (randomized range finder +
/// power refinement), O(d²·r·iters) instead of Jacobi's O(d³·sweeps).
///
/// ‖A‖_F² is computed exactly; only σ_max is estimated, from below, so
/// the approximation can only *overestimate* the stable rank — and the
/// iteration runs until the σ̂² estimate moves by < 1e-5 relative per
/// step. A per-step stall test is sound here because error and
/// convergence rate are coupled: modes that contract slowly (σᵢ ≈ σ₁)
/// contribute almost no error, while modes that contribute error
/// (σᵢ ≤ (1−δ)σ₁) contract by (1−δ)² per step — splitting at the worst
/// δ bounds the accepted relative σ̂² error by ≈ 2√tol ≈ 0.6%, within
/// the 2% contract the tests enforce. If the tolerance is not reached
/// within the iteration cap the function falls back to the exact Jacobi
/// path. The sketch stream is a fixed function of the matrix shape:
/// results are reproducible and thread-count independent.
pub fn stable_rank_approx(a: &Tensor, r: usize) -> f64 {
    let (m, n) = a.dims2();
    let fro2: f64 = a.data.iter().map(|x| (*x as f64).powi(2)).sum();
    if fro2 <= 0.0 || m == 0 || n == 0 {
        return 0.0;
    }
    let r = r.max(1).min(n).min(m);
    let mut rng = crate::rng::Rng::new(
        0x5AB1_E57Au64 ^ ((m as u64) << 32) ^ n as u64,
    );
    let at = transpose(a);
    // range sketch Q ∈ R^{n×r}; a degenerate gaussian draw is
    // probability ~0 but cheap to resample (fresh draws, not a retry of
    // the same sketch)
    let mut q = Tensor::new(vec![n, r], rng.normal_f32_vec(n * r, 1.0));
    if !orthonormalize_columns(&mut q) {
        q = Tensor::new(vec![n, r], rng.normal_f32_vec(n * r, 1.0));
        orthonormalize_columns(&mut q);
    }
    let mut sigma2_prev = 0.0f64;
    for _ in 0..STABLE_RANK_MAX_ITERS {
        let b = matmul(a, &q); // m×r
        let bt = transpose(&b);
        let g = matmul(&bt, &b); // r×r Gram of A·Q
        let sigma2 = singular_values(&g)
            .first()
            .map(|s| *s as f64)
            .unwrap_or(0.0);
        if sigma2 > 0.0
            && (sigma2 - sigma2_prev).abs() <= STABLE_RANK_REL_TOL * sigma2
        {
            return (fro2 / sigma2).max(1.0);
        }
        sigma2_prev = sigma2;
        // power refinement: Q ← orth(Aᵀ·(A·Q)). Rank-deficient A leaves
        // dependent columns near zero — harmless, they contribute
        // nothing to the Rayleigh block.
        let mut z = matmul(&at, &b);
        orthonormalize_columns(&mut z);
        q = z;
    }
    // tolerance not reached (pathological spectrum): exact fallback
    stable_rank(a)
}

/// ‖A − A·U·Uᵀ‖_F — how far A's rows are from S (the "leak" metric used
/// by closure tests and the Grassmann accumulator diagnostics).
pub fn out_of_subspace_norm(a: &Tensor, u: &Tensor) -> f64 {
    let proj = project_rows(a, u);
    a.data
        .iter()
        .zip(&proj.data)
        .map(|(x, p)| ((x - p) as f64).powi(2))
        .sum::<f64>()
        .sqrt()
}

/// Best rank-r approximation error (for the error-accumulation experiment):
/// returns A projected onto its top-r singular subspace via orthogonal
/// iteration. A degenerate sketch is resampled once with fresh RNG
/// draws — enough to rule out an unlucky gaussian draw (probability
/// ~0); a second failure means A itself is rank-deficient, which no
/// sketch can fix, and the dependent columns are zeroed by
/// Gram–Schmidt and drop out of the projection harmlessly.
pub fn low_rank_approx(a: &Tensor, r: usize, rng: &mut crate::rng::Rng) -> Tensor {
    let (_, n) = a.dims2();
    let r = r.min(n);
    let at = transpose(a);
    // Q ← orth(Aᵀ·A·sketch) — one subspace iteration is enough for tests
    let mut q = {
        let sketch = Tensor::new(vec![n, r], rng.normal_f32_vec(n * r, 1.0));
        matmul(&at, &matmul(a, &sketch))
    };
    if !orthonormalize_columns(&mut q) {
        let sketch = Tensor::new(vec![n, r], rng.normal_f32_vec(n * r, 1.0));
        q = matmul(&at, &matmul(a, &sketch));
        orthonormalize_columns(&mut q);
    }
    // A ≈ (A·Q)·Qᵀ
    matmul_nt(&matmul(a, &q), &q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randt(rng: &mut Rng, m: usize, n: usize) -> Tensor {
        Tensor::new(vec![m, n], rng.normal_f32_vec(m * n, 1.0))
    }

    /// A (m×n) with prescribed singular values: U diag(s) Vᵀ from
    /// orthonormalized gaussian U, V. Gives analytically-known stable
    /// rank without running O(d³) Jacobi at large widths.
    fn known_spectrum(
        rng: &mut Rng,
        m: usize,
        n: usize,
        svals: &[f32],
    ) -> (Tensor, f64) {
        let r = svals.len();
        let u = random_orthonormal(m, r, rng);
        let v = random_orthonormal(n, r, rng);
        let mut us = u.clone();
        for (j, s) in svals.iter().enumerate() {
            for i in 0..m {
                us.data[i * r + j] *= s;
            }
        }
        let a = matmul_nt(&us, &v); // U·diag(s)·Vᵀ
        let sum2: f64 = svals.iter().map(|s| (*s as f64).powi(2)).sum();
        let max2 = svals
            .iter()
            .map(|s| (*s as f64).powi(2))
            .fold(0.0f64, f64::max);
        (a, sum2 / max2)
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = randt(&mut rng, 5, 7);
        let mut eye = Tensor::zeros(&[7, 7]);
        for i in 0..7 {
            eye.data[i * 7 + i] = 1.0;
        }
        let c = matmul(&a, &eye);
        assert_eq!(c.data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(vec![2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(matmul(&a, &b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn tiled_matmul_matches_reference_on_odd_shapes() {
        // shapes deliberately not multiples of the 64/256 tiles
        let mut rng = Rng::new(21);
        for (m, k, n) in [(65usize, 130usize, 47usize), (100, 33, 277),
                          (1, 100, 1), (7, 256, 300)] {
            let a = randt(&mut rng, m, k);
            let b = randt(&mut rng, k, n);
            let tiled = matmul(&a, &b);
            let naive = matmul_reference(&a, &b);
            for (x, y) in tiled.data.iter().zip(&naive.data) {
                assert!(
                    (x - y).abs() <= 1e-5 * (1.0 + y.abs()),
                    "({m}x{k}x{n}) tiled {x} vs naive {y}"
                );
            }
        }
    }

    #[test]
    fn matmul_threading_is_bit_stable() {
        // the determinism contract: identical bits for any thread count
        let mut rng = Rng::new(22);
        let a = randt(&mut rng, 128, 128);
        let b = randt(&mut rng, 128, 128);
        let _guard = crate::par::TEST_THREADS_LOCK.lock().unwrap();
        let before = crate::par::max_threads_setting();
        crate::par::set_max_threads(1);
        let c1 = matmul(&a, &b);
        crate::par::set_max_threads(4);
        let c4 = matmul(&a, &b);
        crate::par::set_max_threads(before);
        assert_eq!(c1.data, c4.data);
    }

    #[test]
    fn matmul_nt_matches_transpose_composition() {
        let mut rng = Rng::new(23);
        let a = randt(&mut rng, 19, 37);
        let b = randt(&mut rng, 29, 37);
        let fused = matmul_nt(&a, &b);
        let composed = matmul(&a, &transpose(&b));
        assert_eq!(fused.shape, vec![19, 29]);
        for (x, y) in fused.data.iter().zip(&composed.data) {
            assert!((x - y).abs() <= 1e-5 * (1.0 + y.abs()));
        }
    }

    #[test]
    fn matmul_tn_matches_transpose_composition() {
        let mut rng = Rng::new(24);
        // shapes straddle the threading threshold on both sides
        for (m, k, n) in [(33usize, 17usize, 29usize), (160, 96, 128)] {
            let a = randt(&mut rng, m, k);
            let b = randt(&mut rng, m, n);
            let fused = matmul_tn(&a, &b);
            let composed = matmul(&transpose(&a), &b);
            assert_eq!(fused.shape, vec![k, n]);
            assert_eq!(fused.data, composed.data, "({m}x{k}x{n})");
        }
    }

    #[test]
    fn tiled_matmul_matches_reference_bitwise() {
        // stronger than the tolerance check above: the tile/unroll
        // structure keeps each output element's k-ascending add order,
        // so tiled and naive results must agree to the bit
        let mut rng = Rng::new(27);
        for (m, k, n) in [(65usize, 130usize, 47usize), (7, 256, 300)] {
            let a = randt(&mut rng, m, k);
            let b = randt(&mut rng, k, n);
            assert_eq!(
                matmul(&a, &b).data,
                matmul_reference(&a, &b).data,
                "({m}x{k}x{n})"
            );
        }
    }

    #[test]
    fn matmul_nt_threading_is_bit_stable() {
        let mut rng = Rng::new(28);
        let a = randt(&mut rng, 192, 96);
        let b = randt(&mut rng, 130, 96);
        let _guard = crate::par::TEST_THREADS_LOCK.lock().unwrap();
        let before = crate::par::max_threads_setting();
        crate::par::set_max_threads(1);
        let c1 = matmul_nt(&a, &b);
        crate::par::set_max_threads(4);
        let c4 = matmul_nt(&a, &b);
        crate::par::set_max_threads(before);
        assert_eq!(c1.data, c4.data);
    }

    #[test]
    fn matmul_tn_acc_accumulates_microbatches_exactly() {
        // the fused-gradient contract: per-microbatch accumulate-into
        // calls, in microbatch order, equal ONE matmul_tn over the
        // row-concatenated microbatches — to the bit, at any thread
        // count (the kernel streams the shared m index ascending)
        let mut rng = Rng::new(29);
        let (k, n) = (48usize, 56usize);
        let parts: Vec<(Tensor, Tensor)> = [13usize, 96, 1, 30]
            .iter()
            .map(|m| (randt(&mut rng, *m, k), randt(&mut rng, *m, n)))
            .collect();
        let cat = |sel: fn(&(Tensor, Tensor)) -> &Tensor, cols: usize| {
            let mut data = Vec::new();
            for p in &parts {
                data.extend_from_slice(&sel(p).data);
            }
            Tensor::new(vec![data.len() / cols, cols], data)
        };
        let a_cat = cat(|p| &p.0, k);
        let b_cat = cat(|p| &p.1, n);
        let _guard = crate::par::TEST_THREADS_LOCK.lock().unwrap();
        let before = crate::par::max_threads_setting();
        for threads in [1usize, 4] {
            crate::par::set_max_threads(threads);
            let fused = matmul_tn(&a_cat, &b_cat);
            let mut acc = Tensor::zeros(&[k, n]);
            for (a, b) in &parts {
                matmul_tn_acc(a, b, &mut acc);
            }
            assert_eq!(acc.data, fused.data, "threads={threads}");
        }
        crate::par::set_max_threads(before);
    }

    #[test]
    fn matmul_tn_threading_is_bit_stable() {
        let mut rng = Rng::new(25);
        let a = randt(&mut rng, 256, 96);
        let b = randt(&mut rng, 256, 128);
        let _guard = crate::par::TEST_THREADS_LOCK.lock().unwrap();
        let before = crate::par::max_threads_setting();
        crate::par::set_max_threads(1);
        let c1 = matmul_tn(&a, &b);
        crate::par::set_max_threads(4);
        let c4 = matmul_tn(&a, &b);
        crate::par::set_max_threads(before);
        assert_eq!(c1.data, c4.data);
    }

    #[test]
    fn matmul_tn_skips_relu_zeros_correctly() {
        // exact-zero rows in A (ReLU sparsity) take the skip path; the
        // result must still match the dense composition
        let mut rng = Rng::new(26);
        let mut a = randt(&mut rng, 20, 12);
        for x in a.data.iter_mut() {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
        let b = randt(&mut rng, 20, 8);
        let fused = matmul_tn(&a, &b);
        let composed = matmul(&transpose(&a), &b);
        for (x, y) in fused.data.iter().zip(&composed.data) {
            assert!((x - y).abs() <= 1e-5 * (1.0 + y.abs()));
        }
    }

    #[test]
    fn transpose_involutive() {
        let mut rng = Rng::new(2);
        let a = randt(&mut rng, 3, 8);
        assert_eq!(transpose(&transpose(&a)).data, a.data);
        // exercise the blocked path on tile-straddling shapes
        let b = randt(&mut rng, 45, 70);
        let bt = transpose(&b);
        for i in 0..45 {
            for j in 0..70 {
                assert_eq!(bt.at2(j, i), b.at2(i, j));
            }
        }
    }

    #[test]
    fn orthonormalize_gives_orthonormal_columns() {
        let mut rng = Rng::new(3);
        let mut a = randt(&mut rng, 32, 6);
        assert!(orthonormalize_columns(&mut a));
        let g = matmul(&transpose(&a), &a);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (g.at2(i, j) - want).abs() < 1e-4,
                    "gram[{i},{j}]={}",
                    g.at2(i, j)
                );
            }
        }
    }

    #[test]
    fn orthonormalize_zeroes_dependent_columns() {
        // second column is a multiple of the first: it must come back
        // exactly zero, not a normalized rounding-noise vector with
        // O(0.1) overlap against column 0 (the pre-fix failure mode)
        let mut rng = Rng::new(9);
        let c = rng.normal_f32_vec(32, 1.0);
        let mut data = Vec::with_capacity(64);
        for x in &c {
            data.push(*x);
            data.push(2.0 * x);
        }
        let mut a = Tensor::new(vec![32, 2], data);
        assert!(!orthonormalize_columns(&mut a));
        for i in 0..32 {
            assert_eq!(a.data[i * 2 + 1], 0.0, "row {i} not zeroed");
        }
        let n0: f64 =
            (0..32).map(|i| (a.data[i * 2] as f64).powi(2)).sum();
        assert!((n0.sqrt() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn svd_matches_known_diagonal() {
        // diag(3, 2, 1) embedded in a 4x3
        let mut a = Tensor::zeros(&[4, 3]);
        a.data[0] = 3.0;
        a.data[4] = 2.0;
        a.data[8] = 1.0;
        let sv = singular_values(&a);
        assert!((sv[0] - 3.0).abs() < 1e-4);
        assert!((sv[1] - 2.0).abs() < 1e-4);
        assert!((sv[2] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn svd_frobenius_identity() {
        let mut rng = Rng::new(4);
        let a = randt(&mut rng, 20, 12);
        let sv = singular_values(&a);
        let fro2: f64 = a.data.iter().map(|x| (*x as f64).powi(2)).sum();
        let sv2: f64 = sv.iter().map(|s| (*s as f64).powi(2)).sum();
        assert!(
            (fro2 - sv2).abs() / fro2 < 1e-4,
            "fro²={fro2} Σσ²={sv2}"
        );
    }

    #[test]
    fn stable_rank_of_low_rank_matrix() {
        let mut rng = Rng::new(5);
        // rank-2 matrix: outer products
        let u = randt(&mut rng, 40, 2);
        let v = randt(&mut rng, 2, 30);
        let a = matmul(&u, &v);
        let sr = stable_rank(&a);
        assert!(sr < 2.5, "stable rank {sr} of a rank-2 matrix");
        // full-rank gaussian should have much higher stable rank
        // 40x30 gaussian: ‖A‖_F² ≈ 1200, σ_max ≈ √40+√30 → stable rank ≈ 8.6
        let g = randt(&mut rng, 40, 30);
        assert!(stable_rank(&g) > 6.0);
    }

    #[test]
    fn stable_rank_approx_matches_exact_on_random() {
        let mut rng = Rng::new(31);
        for (m, n) in [(96usize, 128usize), (128, 96), (120, 120)] {
            let a = randt(&mut rng, m, n);
            let exact = stable_rank(&a);
            let approx = stable_rank_approx(&a, STABLE_RANK_SKETCH);
            assert!(
                (approx - exact).abs() <= 0.02 * exact,
                "({m}x{n}) approx {approx} vs exact {exact}"
            );
        }
    }

    #[test]
    fn stable_rank_approx_low_rank_wide() {
        // rank-3 with known spectrum at width 512: exact value analytic
        let mut rng = Rng::new(32);
        let (a, want) =
            known_spectrum(&mut rng, 512, 256, &[5.0, 3.0, 1.0]);
        let approx = stable_rank_approx(&a, STABLE_RANK_SKETCH);
        assert!(
            (approx - want).abs() <= 0.02 * want,
            "approx {approx} vs analytic {want}"
        );
    }

    #[test]
    fn stable_rank_approx_ill_conditioned() {
        // geometric spectrum over 6 decades, 512 wide (analytic truth)
        let mut rng = Rng::new(33);
        let svals: Vec<f32> = (0..12)
            .map(|i| 1e3 * (10f32).powf(-0.5 * i as f32))
            .collect();
        let (a, want) = known_spectrum(&mut rng, 512, 512, &svals);
        let approx = stable_rank_approx(&a, STABLE_RANK_SKETCH);
        assert!(
            (approx - want).abs() <= 0.02 * want,
            "approx {approx} vs analytic {want}"
        );
        // near-degenerate top pair: the block must capture both
        let (b, want2) =
            known_spectrum(&mut rng, 256, 256, &[4.0, 3.999, 2.0, 0.5]);
        let approx2 = stable_rank_approx(&b, STABLE_RANK_SKETCH);
        assert!(
            (approx2 - want2).abs() <= 0.02 * want2,
            "approx {approx2} vs analytic {want2}"
        );
    }

    #[test]
    fn stable_rank_approx_zero_matrix() {
        let z = Tensor::zeros(&[17, 9]);
        assert_eq!(stable_rank_approx(&z, 4), 0.0);
    }

    #[test]
    fn project_rows_idempotent() {
        let mut rng = Rng::new(6);
        let u = random_orthonormal(16, 4, &mut rng);
        let w = randt(&mut rng, 10, 16);
        let p1 = project_rows(&w, &u);
        let p2 = project_rows(&p1, &u);
        for (a, b) in p1.data.iter().zip(&p2.data) {
            assert!((a - b).abs() < 1e-4);
        }
        assert!(out_of_subspace_norm(&p1, &u) < 1e-3);
    }

    #[test]
    fn low_rank_approx_reduces_error_with_rank() {
        let mut rng = Rng::new(7);
        let a = randt(&mut rng, 24, 24);
        let e2 = {
            let ap = low_rank_approx(&a, 2, &mut rng);
            a.data.iter().zip(&ap.data).map(|(x, y)| (x - y).powi(2)).sum::<f32>()
        };
        let e16 = {
            let ap = low_rank_approx(&a, 16, &mut rng);
            a.data.iter().zip(&ap.data).map(|(x, y)| (x - y).powi(2)).sum::<f32>()
        };
        assert!(e16 < e2, "rank-16 err {e16} !< rank-2 err {e2}");
    }

    #[test]
    fn low_rank_approx_rank_deficient_regression() {
        // rank-2 input, rank-8 request: the sketch is necessarily
        // degenerate — the old code retried orthonormalization on the
        // same sketch (a no-op); the fix resamples, and residual
        // dependent columns drop out. The approximation must still
        // reconstruct A (it has rank ≤ requested) with no NaNs.
        let mut rng = Rng::new(8);
        let u = randt(&mut rng, 64, 2);
        let v = randt(&mut rng, 2, 48);
        let a = matmul(&u, &v);
        let ap = low_rank_approx(&a, 8, &mut rng);
        assert!(ap.data.iter().all(|x| x.is_finite()));
        let num: f64 = a
            .data
            .iter()
            .zip(&ap.data)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let den: f64 = a
            .data
            .iter()
            .map(|x| (*x as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(num / den < 1e-2, "relative error {}", num / den);
        // the fully-degenerate extreme: a zero matrix (every sketch
        // fails) must come back as zeros, not NaNs
        let z = Tensor::zeros(&[12, 10]);
        let zp = low_rank_approx(&z, 4, &mut rng);
        assert!(zp.data.iter().all(|x| x.is_finite() && x.abs() < 1e-6));
    }
}
