//! Stage compute-time models for the virtual clock.
//!
//! `Measured` uses real PJRT wall times (this process, CPU). `Analytic`
//! prices the stage's FLOPs at a configurable accelerator throughput so
//! the compute/communication ratio matches the paper's A10G/L4-class
//! deployments — required to reproduce the square-cube-law behaviour
//! (Fig. 3) and the wall-clock convergence plots (Figs. 2, 5) at our
//! (smaller) model scale. Loss values are always real; only the clock is
//! modeled. Default throughput: 30 TFLOP/s effective (A10G-class tensor
//! cores at ~25% MFU).

use crate::manifest::Hyper;

/// How stage compute time is priced on the virtual clock.
#[derive(Clone, Copy, Debug)]
pub enum TimeModel {
    /// real PJRT execution seconds measured in this process
    Measured,
    /// FLOPs / device_flops
    Analytic { device_flops: f64 },
}

impl TimeModel {
    /// Parse a CLI label: `"measured"`, `"analytic"`, `"analytic:<TFLOPs>"`.
    pub fn parse(s: &str) -> Option<TimeModel> {
        if s == "measured" {
            return Some(TimeModel::Measured);
        }
        if s == "analytic" {
            return Some(TimeModel::default_analytic());
        }
        if let Some(rest) = s.strip_prefix("analytic:") {
            let tf: f64 = rest.parse().ok()?;
            return Some(TimeModel::Analytic { device_flops: tf * 1e12 });
        }
        None
    }

    /// Effective accelerator throughput chosen so that the
    /// compute : communication ratio of our reduced-scale configs matches
    /// the paper's 2B-on-A10G deployment (fwd ≈ 0.58 s/stage vs ≈ 51 s
    /// raw-activation transfer at 80 Mbps → ratio ≈ 0.011; our base
    /// config reproduces that at ≈ 2 TFLOP/s). See DESIGN.md §4.
    pub fn default_analytic() -> TimeModel {
        TimeModel::Analytic { device_flops: 2e12 }
    }

    /// Scale this model for a heterogeneous replica: a `slowdown` of 2.0
    /// models a straggler with half the effective throughput. Only the
    /// analytic model scales; `Measured` times are real wall-clock of
    /// *this* process and cannot be re-attributed, so they pass through
    /// (replicated straggler experiments should use analytic models).
    pub fn scaled(self, slowdown: f64) -> TimeModel {
        match self {
            TimeModel::Measured => TimeModel::Measured,
            TimeModel::Analytic { device_flops } => TimeModel::Analytic {
                device_flops: device_flops / slowdown.max(1e-9),
            },
        }
    }

    /// [`TimeModel::scaled`] driven by a *time-varying* straggler
    /// profile, evaluated at simulated instant `t` — real swarm hosts
    /// don't straggle by a constant factor, they degrade and recover
    /// (thermal throttling, co-tenant load). The discrete-event
    /// simulator prices each step's compute at the profile's factor at
    /// the step's start.
    pub fn scaled_at(self, profile: &SlowdownProfile, t: f64) -> TimeModel {
        self.scaled(profile.at(t))
    }
}

/// Compute-slowdown trajectory of one replica over simulated time
/// (1.0 = nominal throughput, 2.0 = half throughput).
#[derive(Clone, Debug)]
pub enum SlowdownProfile {
    /// the same factor for the whole run — equivalent to the static
    /// `--hetero` factors fed to [`TimeModel::scaled`]
    Constant(f64),
    /// piecewise-constant phases `(start_seconds, factor)`: at time t
    /// the factor of the last phase with `start <= t` applies (1.0
    /// before the first phase). Phases must be sorted by start time.
    Phases(Vec<(f64, f64)>),
}

impl SlowdownProfile {
    /// Nominal (no-slowdown) profile.
    pub fn nominal() -> SlowdownProfile {
        SlowdownProfile::Constant(1.0)
    }

    /// Slowdown factor at simulated instant `t`.
    pub fn at(&self, t: f64) -> f64 {
        match self {
            SlowdownProfile::Constant(f) => *f,
            SlowdownProfile::Phases(phases) => {
                let mut cur = 1.0;
                for (start, factor) in phases {
                    if *start <= t {
                        cur = *factor;
                    } else {
                        break;
                    }
                }
                cur
            }
        }
    }

    /// Whether every factor is finite and positive and phase starts are
    /// sorted — validated by simulation specs before running.
    pub fn is_valid(&self) -> bool {
        match self {
            SlowdownProfile::Constant(f) => f.is_finite() && *f > 0.0,
            SlowdownProfile::Phases(phases) => {
                phases.iter().all(|(s, f)| {
                    s.is_finite() && *s >= 0.0 && f.is_finite() && *f > 0.0
                }) && phases.windows(2).all(|w| w[0].0 <= w[1].0)
            }
        }
    }
}

/// Which entrypoint's cost to estimate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// forward through one stage's blocks
    Fwd,
    /// recompute-backward (fwd again + bwd ≈ 3× fwd)
    Bwd,
    /// last stage: fwd + loss + bwd fused
    LastLoss,
    /// optimizer step over one stage's params
    Opt,
    /// Grassmann subspace step (d×d×k)
    Grassmann,
}

/// FLOPs of one transformer block, fwd only (standard 2·mn·k per matmul).
pub fn block_flops(b: usize, n: usize, d: usize, d_ff: usize) -> f64 {
    let bn = (b * n) as f64;
    let qkvo = 8.0 * bn * (d * d) as f64; // Wq, Wk, Wv, Wp1
    let attn = 4.0 * (b) as f64 * (n * n) as f64 * d as f64; // QKᵀ + AV
    let mlp = 4.0 * bn * (d * d_ff) as f64; // W1 + Wp2
    qkvo + attn + mlp
}

/// Boundary projection/reconstruction FLOPs (the L1 kernels): 2·bn·d·k each.
pub fn boundary_flops(b: usize, n: usize, d: usize, k: usize) -> f64 {
    2.0 * (b * n) as f64 * (d * k) as f64
}

/// FLOPs for one stage to decode ONE new position of one serving session
/// (`serve-infer`, DESIGN.md §16): matvecs against the block weights plus
/// attention over the `pos + 1`-row cached prefix, plus the stage's
/// boundary / embedding / head extras. Mirrors [`StageDecoder::step`]'s
/// arithmetic the way [`stage_flops`] mirrors the training forward.
///
/// [`StageDecoder::step`]: crate::nn::decode::StageDecoder::step
pub fn decode_row_flops(h: &Hyper, stage: usize, pos: usize, compressed: bool) -> f64 {
    let d = h.d as f64;
    let prefix = (pos + 1) as f64;
    // per block: q/k/v/proj matvecs (4 · 2d²), MLP (2 · 2·d·d_ff),
    // attention scores + weighted sum over the prefix (2 · 2·prefix·d)
    let block =
        8.0 * d * d + 4.0 * d * h.d_ff as f64 + 4.0 * prefix * d;
    let mut f = h.blocks_per_stage as f64 * block;
    if stage == 0 {
        f += 2.0 * d; // embedding gather + scale
    }
    if stage == h.stages - 1 {
        f += 2.0 * d * h.vocab as f64; // LM-head matvec
    }
    if compressed {
        // boundary project on the send side, reconstruct on the recv side
        let bnd = 2.0 * d * h.k as f64;
        if stage < h.stages - 1 {
            f += bnd;
        }
        if stage > 0 {
            f += bnd;
        }
    }
    f
}

/// FLOPs for one stage executing `phase` on a single microbatch.
pub fn stage_flops(h: &Hyper, stage: usize, phase: Phase, compressed: bool) -> f64 {
    let blocks = h.blocks_per_stage as f64
        * block_flops(h.b, h.n, h.d, h.d_ff);
    let bnd = (h.b * h.n * h.d) as f64;
    let head = if stage == h.stages - 1 {
        2.0 * (h.b * h.n) as f64 * (h.d * h.vocab) as f64
    } else {
        0.0
    };
    let embed = if stage == 0 { 2.0 * bnd } else { 0.0 };
    let bproj = if compressed {
        2.0 * boundary_flops(h.b, h.n, h.d, h.k)
    } else {
        0.0
    };
    let fwd = blocks + head + embed + bproj;
    match phase {
        Phase::Fwd => fwd,
        Phase::Bwd => 3.0 * fwd, // remat: fwd recompute + 2×fwd backward
        Phase::LastLoss => 3.0 * fwd,
        Phase::Opt => {
            // elementwise AdamW ≈ 12 flops/param + W_p1 projection 2·d·d·k
            12.0 * stage_param_count(h, stage) as f64
                + if compressed {
                    2.0 * (h.d * h.d * h.k) as f64
                } else {
                    0.0
                }
        }
        Phase::Grassmann => 4.0 * (h.d * h.d * h.k) as f64,
    }
}

/// Analytic per-stage parameter element count, derived from the config
/// dimensions alone (no manifest needed): blocks (4 d² attention + 2 d·d_ff
/// MLP + 4 d norms), plus the embedding table on stage 0 and the final
/// norm + LM head on the last stage. Sizes the data-parallel gradient
/// all-reduce payloads in `coordinator::replica`.
pub fn stage_param_count(h: &Hyper, stage: usize) -> usize {
    let block = 4 * h.d * h.d + 2 * h.d * h.d_ff + 4 * h.d;
    let mut p = h.blocks_per_stage * block;
    if stage == 0 {
        p += h.vocab * h.d;
    }
    if stage == h.stages - 1 {
        p += h.vocab * h.d + 2 * h.d;
    }
    p
}

/// Seconds for a stage phase under this time model. `measured` supplies
/// the real PJRT mean seconds when available.
pub fn stage_seconds(
    model: TimeModel,
    h: &Hyper,
    stage: usize,
    phase: Phase,
    compressed: bool,
    measured: Option<f64>,
) -> f64 {
    match model {
        TimeModel::Measured => measured.unwrap_or(0.0),
        TimeModel::Analytic { device_flops } => {
            stage_flops(h, stage, phase, compressed) / device_flops
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hyper() -> Hyper {
        Hyper {
            d: 256,
            d_ff: 1024,
            heads: 8,
            layers: 8,
            stages: 4,
            n: 128,
            vocab: 1024,
            k: 4,
            b: 4,
            blocks_per_stage: 2,
            ratio: 64.0,
            param_count: 0,
        }
    }

    #[test]
    fn bwd_is_3x_fwd() {
        let h = hyper();
        let f = stage_flops(&h, 1, Phase::Fwd, true);
        let b = stage_flops(&h, 1, Phase::Bwd, true);
        assert!((b / f - 3.0).abs() < 1e-9);
    }

    #[test]
    fn last_stage_costs_more_than_mid() {
        let h = hyper();
        assert!(
            stage_flops(&h, 3, Phase::Fwd, true)
                > stage_flops(&h, 1, Phase::Fwd, true)
        );
    }

    #[test]
    fn boundary_projection_is_marginal() {
        // the paper's §6: weight projection + boundary kernels ≈ 1%
        let h = hyper();
        let with = stage_flops(&h, 1, Phase::Fwd, true);
        let without = stage_flops(&h, 1, Phase::Fwd, false);
        assert!((with - without) / without < 0.02);
    }

    #[test]
    fn square_cube_law_direction() {
        // doubling d quadruples (≈) compute but only doubles boundary bytes
        let mut h = hyper();
        let f1 = stage_flops(&h, 1, Phase::Fwd, false);
        h.d *= 2;
        h.d_ff *= 2;
        let f2 = stage_flops(&h, 1, Phase::Fwd, false);
        assert!(f2 > 3.0 * f1, "compute should scale ≳ quadratically in d");
    }

    #[test]
    fn analytic_seconds_scale_inverse_with_flops() {
        let h = hyper();
        let fast = stage_seconds(
            TimeModel::Analytic { device_flops: 100e12 },
            &h,
            1,
            Phase::Fwd,
            true,
            None,
        );
        let slow = stage_seconds(
            TimeModel::Analytic { device_flops: 10e12 },
            &h,
            1,
            Phase::Fwd,
            true,
            None,
        );
        assert!((slow / fast - 10.0).abs() < 1e-6);
    }

    #[test]
    fn scaled_slowdown_scales_seconds() {
        let h = hyper();
        let base = stage_seconds(
            TimeModel::default_analytic(), &h, 1, Phase::Fwd, true, None,
        );
        let slow = stage_seconds(
            TimeModel::default_analytic().scaled(2.0),
            &h, 1, Phase::Fwd, true, None,
        );
        assert!((slow / base - 2.0).abs() < 1e-9);
        // Measured passes through unscaled
        assert!(matches!(
            TimeModel::Measured.scaled(3.0),
            TimeModel::Measured
        ));
    }

    #[test]
    fn slowdown_profile_phases_and_validation() {
        let p = SlowdownProfile::Phases(vec![(10.0, 2.0), (20.0, 1.0)]);
        assert_eq!(p.at(0.0), 1.0, "nominal before the first phase");
        assert_eq!(p.at(10.0), 2.0);
        assert_eq!(p.at(15.0), 2.0);
        assert_eq!(p.at(25.0), 1.0);
        assert!(p.is_valid());
        assert!(SlowdownProfile::nominal().is_valid());
        assert!(!SlowdownProfile::Constant(0.0).is_valid());
        assert!(!SlowdownProfile::Constant(f64::NAN).is_valid());
        assert!(
            !SlowdownProfile::Phases(vec![(5.0, 1.0), (1.0, 2.0)]).is_valid(),
            "unsorted phases rejected"
        );

        // scaled_at routes through the profile factor
        let h = hyper();
        let base = stage_seconds(
            TimeModel::default_analytic(), &h, 1, Phase::Fwd, true, None,
        );
        let slow = stage_seconds(
            TimeModel::default_analytic().scaled_at(&p, 12.0),
            &h, 1, Phase::Fwd, true, None,
        );
        assert!((slow / base - 2.0).abs() < 1e-9);
    }

    #[test]
    fn stage_param_counts_cover_embedding_and_head() {
        let h = hyper();
        let mid = stage_param_count(&h, 1);
        assert!(stage_param_count(&h, 0) > mid, "stage 0 owns t_s");
        assert!(stage_param_count(&h, h.stages - 1) > mid, "last owns head");
        let block = 4 * h.d * h.d + 2 * h.d * h.d_ff + 4 * h.d;
        assert_eq!(mid, h.blocks_per_stage * block);
    }

    #[test]
    fn parse_variants() {
        assert!(matches!(TimeModel::parse("measured"), Some(TimeModel::Measured)));
        match TimeModel::parse("analytic:5") {
            Some(TimeModel::Analytic { device_flops }) => {
                assert!((device_flops - 5e12).abs() < 1.0)
            }
            _ => panic!(),
        }
        assert!(TimeModel::parse("bogus").is_none());
    }
}
