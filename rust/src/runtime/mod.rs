//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the coordinator hot path. Python never runs here.
//!
//! Artifacts are HLO *text* (see compile/aot.py): `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `client.compile`.
//! Executables are cached per entry key ("mode/entry"); every execution
//! is timed so the coordinator's measured time-model can feed netsim.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::manifest::{ConfigManifest, Dtype, Entry, Manifest};
use crate::tensor::{IntTensor, Tensor, Value};

/// A runtime shared by several pipelines (replicated data-parallel runs):
/// one PJRT client and one compiled-executable cache serve every replica,
/// so R replicas pay the compile cost once instead of R times. All
/// replica coordination is single-threaded, hence `Rc<RefCell<…>>` —
/// this type is **not** `Send`. Parallel experiment grids therefore
/// never share a runtime: each grid cell constructs an *owned* `Runtime`
/// inside its pool worker (`coordinator::RtHandle::Owned`) and drops it
/// there, which also keeps PJRT clients strictly thread-local.
pub type SharedRuntime = Rc<RefCell<Runtime>>;

/// PJRT execution engine for one config: compiles AOT HLO-text artifacts
/// lazily and executes them from the coordinator hot path.
pub struct Runtime {
    client: xla::PjRtClient,
    cfg: ConfigManifest,
    root: std::path::PathBuf,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// per-entry (executions, cumulative seconds) — feeds the measured
    /// time model and the §Perf profile
    pub timings: HashMap<String, (u64, f64)>,
}

impl Runtime {
    /// Create a runtime for one config; entries compile lazily on first use.
    pub fn new(manifest: &Manifest, config: &str) -> Result<Runtime> {
        let cfg = manifest.config(config)?.clone();
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            cfg,
            root: manifest.root.clone(),
            exes: HashMap::new(),
            timings: HashMap::new(),
        })
    }

    /// Create a runtime wrapped for sharing across pipeline replicas.
    pub fn shared(manifest: &Manifest, config: &str) -> Result<SharedRuntime> {
        Ok(Rc::new(RefCell::new(Runtime::new(manifest, config)?)))
    }

    /// Whether a real PJRT backend is linked. `false` under the offline
    /// `xla` stub — execution paths error and artifact-dependent tests
    /// skip themselves when this is false.
    pub fn backend_available() -> bool {
        xla::backend_available()
    }

    /// The config manifest this runtime was built for.
    pub fn config(&self) -> &ConfigManifest {
        &self.cfg
    }

    /// Compile (and cache) the executable for an entry key.
    pub fn ensure(&mut self, key: &str) -> Result<()> {
        if self.exes.contains_key(key) {
            return Ok(());
        }
        let entry = self.cfg.entry(key)?;
        let path = self.root.join(&entry.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {key}: {e:?}"))?;
        let dt = t0.elapsed().as_secs_f64();
        if std::env::var_os("PROTOMODELS_VERBOSE").is_some() {
            eprintln!("[runtime] compiled {key} in {dt:.2}s");
        }
        self.exes.insert(key.to_string(), exe);
        Ok(())
    }

    /// Pre-compile a set of entries (pipeline warmup).
    pub fn warmup(&mut self, keys: &[&str]) -> Result<()> {
        for k in keys {
            self.ensure(k)?;
        }
        Ok(())
    }

    fn to_literal(v: &Value) -> Result<xla::Literal> {
        let dims: Vec<i64> = v.shape().iter().map(|&d| d as i64).collect();
        let lit = match v {
            Value::F32(t) => {
                if t.is_scalar() {
                    xla::Literal::scalar(t.data[0])
                } else {
                    xla::Literal::vec1(&t.data)
                        .reshape(&dims)
                        .map_err(|e| anyhow::anyhow!("reshape f32: {e:?}"))?
                }
            }
            Value::I32(t) => xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshape i32: {e:?}"))?,
        };
        Ok(lit)
    }

    fn check_args(entry: &Entry, key: &str, args: &[Value]) -> Result<()> {
        if entry.args.len() != args.len() {
            bail!(
                "{key}: expected {} args, got {}",
                entry.args.len(),
                args.len()
            );
        }
        for (spec, v) in entry.args.iter().zip(args) {
            if spec.shape != v.shape() {
                bail!(
                    "{key}: arg {:?} shape {:?} != provided {:?}",
                    spec.name,
                    spec.shape,
                    v.shape()
                );
            }
            let ok = matches!(
                (spec.dtype, v),
                (Dtype::F32, Value::F32(_)) | (Dtype::I32, Value::I32(_))
            );
            if !ok {
                bail!("{key}: arg {:?} dtype mismatch", spec.name);
            }
        }
        Ok(())
    }

    /// Execute an entry. Returns the flattened outputs (manifest order).
    pub fn execute(&mut self, key: &str, args: &[Value]) -> Result<Vec<Value>> {
        Ok(self.execute_timed(key, args)?.0)
    }

    /// Execute an entry, returning outputs + this call's wall seconds
    /// (feeds the measured time model).
    pub fn execute_timed(
        &mut self,
        key: &str,
        args: &[Value],
    ) -> Result<(Vec<Value>, f64)> {
        self.ensure(key)?;
        let entry = self.cfg.entry(key)?.clone();
        Self::check_args(&entry, key, args)?;
        let lits: Vec<xla::Literal> = args
            .iter()
            .map(Self::to_literal)
            .collect::<Result<_>>()?;

        let t0 = Instant::now();
        let exe = self.exes.get(key).unwrap();
        let out_bufs = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("execute {key}: {e:?}"))?;
        let result = out_bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {key}: {e:?}"))?;
        let dt = t0.elapsed().as_secs_f64();
        let t = self.timings.entry(key.to_string()).or_insert((0, 0.0));
        t.0 += 1;
        t.1 += dt;

        // AOT lowers with return_tuple=True → single tuple literal
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {key}: {e:?}"))?;
        if parts.len() != entry.outs.len() {
            bail!(
                "{key}: {} outputs, manifest says {}",
                parts.len(),
                entry.outs.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&entry.outs) {
            let v = match spec.dtype {
                Dtype::F32 => Value::F32(Tensor::new(
                    spec.shape.clone(),
                    lit.to_vec::<f32>()
                        .map_err(|e| anyhow::anyhow!("read f32: {e:?}"))?,
                )),
                Dtype::I32 => Value::I32(IntTensor::new(
                    spec.shape.clone(),
                    lit.to_vec::<i32>()
                        .map_err(|e| anyhow::anyhow!("read i32: {e:?}"))?,
                )),
            };
            outs.push(v);
        }
        Ok((outs, dt))
    }

    /// Mean measured execution seconds for an entry (None if never run).
    pub fn mean_time(&self, key: &str) -> Option<f64> {
        self.timings.get(key).map(|(n, t)| t / (*n).max(1) as f64)
    }

    /// Total runtime seconds across all entries (profiling).
    pub fn total_compute_seconds(&self) -> f64 {
        self.timings.values().map(|(_, t)| t).sum()
    }

    /// Structured per-entry timing table (profiling); its `Display`
    /// renders the legacy `entry,calls,total_s,mean_ms` CSV text.
    pub fn timing_report(&self) -> crate::obs::counters::TimingReport {
        crate::obs::counters::TimingReport::from_timings(&self.timings)
    }
}
