//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so the subset of the
//! anyhow API this workspace uses is implemented here: the type-erased
//! [`Error`], the [`Result`] alias, the `anyhow!` / `bail!` macros, and
//! the [`Context`] extension trait for `Result` and `Option`. Error
//! messages are flattened to strings (context prefixes joined with `: `),
//! which is all the callers ever render.

use std::fmt;

/// A type-erased error: a display message plus an optional source chain
/// (flattened into the message at construction time).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prefix this error with additional context, anyhow-style.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion legal.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()` to `Result`
/// and `Option`, as in anyhow.
pub trait Context<T> {
    /// Attach a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error { msg: ctx.to_string() })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error { msg: f().to_string() })
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        Ok(std::fs::read_to_string("/nonexistent/definitely/missing")?)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| "outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let o: Option<u8> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        fn f() -> Result<()> {
            bail!("boom {}", 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
    }
}
