//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links libxla / PJRT, which cannot be built in this
//! offline environment. This stub exposes the exact API subset the
//! `protomodels` runtime consumes so the workspace always compiles and
//! unit tests run; any attempt to *compile or execute* an HLO program
//! returns a descriptive error. `backend_available()` lets callers (and
//! tests) detect the stub and skip execution paths gracefully.
//!
//! Literal construction/reshaping/reading is fully functional — only the
//! compiler/executor is absent.

use std::borrow::Borrow;
use std::path::Path;

/// Error type mirroring xla-rs's (callers only format it with `{:?}`).
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

/// Result alias used throughout the stub.
pub type Result<T> = std::result::Result<T, XlaError>;

const NO_BACKEND: &str = "PJRT backend unavailable: this build uses the \
     offline `xla` stub (rust/vendor/xla). Link the real xla-rs bindings \
     to execute AOT artifacts (DESIGN.md §4)";

/// True when a real PJRT backend is linked. Always false in the stub.
pub fn backend_available() -> bool {
    false
}

/// Handle to a PJRT client (CPU only in this codebase).
pub struct PjRtClient;

impl PjRtClient {
    /// Create the CPU client. Succeeds in the stub; only compilation fails.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    /// Compile a computation. Always fails in the stub.
    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError(NO_BACKEND.to_string()))
    }
}

/// Parsed HLO module (the stub only retains the raw text).
pub struct HloModuleProto {
    /// Raw HLO text as read from disk.
    pub text: String,
}

impl HloModuleProto {
    /// Read an HLO text artifact. Functional in the stub (I/O only).
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| XlaError(format!("{}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Element storage for a [`Literal`] (public only because the
/// [`NativeType`] trait mentions it; not part of the real xla-rs API).
#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host-side typed array exchanged with the runtime.
#[derive(Clone, Debug)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

/// Sealed-ish helper trait for the element types `Literal` supports.
pub trait NativeType: Copy {
    /// Wrap a slice of this type into a payload.
    fn wrap(data: &[Self]) -> Payload;
    /// Extract a vector of this type, if the payload matches.
    fn unwrap(p: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: &[Self]) -> Payload {
        Payload::F32(data.to_vec())
    }
    fn unwrap(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[Self]) -> Payload {
        Payload::I32(data.to_vec())
    }
    fn unwrap(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Scalar f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { payload: Payload::F32(vec![v]), dims: vec![] }
    }

    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            payload: T::wrap(data),
            dims: vec![data.len() as i64],
        }
    }

    /// Reshape to the given dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        let have = match &self.payload {
            Payload::F32(v) => v.len() as i64,
            Payload::I32(v) => v.len() as i64,
        };
        if numel != have {
            return Err(XlaError(format!(
                "reshape: {have} elements into shape {dims:?}"
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    /// Split a tuple literal into its parts. The stub never produces
    /// tuples (nothing executes), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(XlaError(NO_BACKEND.to_string()))
    }

    /// Read the elements out as a `Vec<T>`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.payload)
            .ok_or_else(|| XlaError("literal dtype mismatch".to_string()))
    }
}

/// Device buffer handle returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal. Unreachable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError(NO_BACKEND.to_string()))
    }
}

/// A compiled executable. Never constructed by the stub.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals. Unreachable in the stub.
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError(NO_BACKEND.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
        assert!(r.to_vec::<i32>().is_err());
        let i = Literal::vec1(&[7i32]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn backend_is_reported_unavailable() {
        assert!(!backend_available());
        let client = PjRtClient::cpu().unwrap();
        assert!(client.compile(&XlaComputation).is_err());
    }
}
