//! §6 reproduction: computational overhead of the subspace machinery.
//!
//! The paper reports weight projection ≈ 1% of a forward pass and
//! Grassmann updates negligible (amortized over 500 steps). We measure
//! real PJRT wall times of the corresponding programs and print the same
//! ratios.

use protomodels::bench::Bencher;
use protomodels::compress::Mode;
use protomodels::manifest::Manifest;
use protomodels::rng::Rng;
use protomodels::runtime::Runtime;
use protomodels::stage::{GlobalState, StageState};
use protomodels::tensor::{IntTensor, Tensor, Value};

fn main() {
    let m = Manifest::load(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    )
    .expect("run `make artifacts`");
    let config = "base";
    let cm = m.config(config).unwrap().clone();
    let h = cm.hyper.clone();
    let mut rt = Runtime::new(&m, config).unwrap();
    let mut rng = Rng::new(1);
    let global = GlobalState::init(&cm, &mut rng);
    let st1 =
        StageState::init(&cm, 1, Mode::Subspace, &global, &mut rng).unwrap();
    let tok = IntTensor::new(
        vec![h.b, h.n],
        (0..h.b * h.n).map(|i| (i % h.vocab) as i32).collect(),
    );
    let xc = Tensor::new(
        vec![h.b, h.n, h.k],
        rng.normal_f32_vec(h.b * h.n * h.k, 1.0),
    );

    let ctx = |st: &StageState| -> Vec<Value> {
        let mut a: Vec<Value> =
            st.params.iter().cloned().map(Value::F32).collect();
        a.push(Value::F32(global.u.clone()));
        a.push(Value::F32(global.t_fixed.clone()));
        a.push(Value::I32(tok.clone()));
        a
    };

    let bench = Bencher::quick();

    // forward pass of a mid stage
    let mut fwd_args = ctx(&st1);
    fwd_args.push(Value::F32(xc.clone()));
    rt.execute("subspace/mid_fwd", &fwd_args).unwrap();
    let fwd = bench.run("mid stage forward (subspace)", || {
        rt.execute("subspace/mid_fwd", &fwd_args).unwrap();
    });

    // optimizer step incl. W_p1 projection + row-wise kernel
    let grads: Vec<Value> =
        st1.params.iter().map(|p| Value::F32(Tensor::zeros(&p.shape))).collect();
    let mut opt_args: Vec<Value> =
        st1.params.iter().cloned().map(Value::F32).collect();
    opt_args.extend(grads.iter().cloned());
    opt_args.extend(st1.m.iter().cloned().map(Value::F32));
    opt_args.extend(st1.v.iter().cloned().map(Value::F32));
    opt_args.push(Value::F32(global.u.clone()));
    opt_args.push(Value::F32(Tensor::scalar(1e-3)));
    opt_args.push(Value::F32(Tensor::scalar(10.0)));
    rt.execute("subspace/adamw_mid", &opt_args).unwrap();
    let opt = bench.run("adamw_mid (incl. weight projection)", || {
        rt.execute("subspace/adamw_mid", &opt_args).unwrap();
    });

    // reproject (pure weight projection — the §6 "weight projection" op)
    let mut rep_args: Vec<Value> =
        st1.params.iter().cloned().map(Value::F32).collect();
    rep_args.extend(st1.m.iter().cloned().map(Value::F32));
    rep_args.push(Value::F32(global.u.clone()));
    rt.execute("subspace/reproject_mid", &rep_args).unwrap();
    let rep = bench.run("weight projection (reproject_mid)", || {
        rt.execute("subspace/reproject_mid", &rep_args).unwrap();
    });

    // Grassmann step
    let s_acc = Tensor::new(
        vec![h.d, h.d],
        rng.normal_f32_vec(h.d * h.d, 1.0),
    );
    let g_args = vec![
        Value::F32(global.u.clone()),
        Value::F32(s_acc),
        Value::F32(Tensor::scalar(1e-3)),
    ];
    rt.execute("subspace/grassmann_step", &g_args).unwrap();
    let gr = bench.run("grassmann_step (d×d·k + retraction)", || {
        rt.execute("subspace/grassmann_step", &g_args).unwrap();
    });

    println!("\n== §6 overhead ratios (vs one stage forward) ==");
    println!(
        "weight projection: {:.2}%   (paper: ≈1%)",
        100.0 * rep.mean_ns / fwd.mean_ns
    );
    println!(
        "optimizer step:    {:.2}%",
        100.0 * opt.mean_ns / fwd.mean_ns
    );
    println!(
        "grassmann (per-500-step amortized): {:.4}%",
        100.0 * gr.mean_ns / fwd.mean_ns / 500.0
    );
}
