//! Host linalg micro-benchmarks: the off-hot-path substrate used by
//! metrics (stable rank), init (orthonormal U, row projection) and the
//! Grassmann diagnostics. `protomodels bench --json` runs the tracked
//! subset of these and writes BENCH_linalg.json (DESIGN.md §8).

use protomodels::bench::{black_box, Bencher};
use protomodels::linalg::{
    matmul, matmul_reference, orthonormalize_columns, project_rows,
    singular_values, stable_rank, stable_rank_approx, transpose,
    STABLE_RANK_SKETCH,
};
use protomodels::rng::Rng;
use protomodels::tensor::Tensor;

fn randt(rng: &mut Rng, m: usize, n: usize) -> Tensor {
    Tensor::new(vec![m, n], rng.normal_f32_vec(m * n, 1.0))
}

fn main() {
    let mut rng = Rng::new(3);
    let a256 = randt(&mut rng, 256, 256);
    let b256 = randt(&mut rng, 256, 256);
    let w = randt(&mut rng, 1024, 256);
    let u = {
        let mut u = randt(&mut rng, 256, 8);
        orthonormalize_columns(&mut u);
        u
    };
    let bench = Bencher::default();

    for (name, f) in [
        (
            "matmul tiled 256x256x256",
            matmul as fn(&Tensor, &Tensor) -> Tensor,
        ),
        ("matmul reference 256x256x256", matmul_reference),
    ] {
        let r = bench.run(name, || {
            black_box(f(black_box(&a256), black_box(&b256)));
        });
        println!(
            "    -> {:.2} GFLOP/s",
            2.0 * 256f64.powi(3) / (r.mean_ns * 1e-9) / 1e9
        );
    }
    bench.run("transpose 256x256", || {
        black_box(transpose(black_box(&a256)));
    });
    bench.run("project_rows fused (1024x256)x(256x8)", || {
        black_box(project_rows(black_box(&w), black_box(&u)));
    });
    let quick = Bencher::quick();
    quick.run("singular_values 128x128 (jacobi)", || {
        let m = randt(&mut Rng::new(9), 128, 128);
        black_box(singular_values(&m));
    });
    quick.run("stable_rank exact 256x256 (jacobi)", || {
        black_box(stable_rank(black_box(&a256)));
    });
    quick.run("stable_rank_approx 256x256 (range-finder)", || {
        black_box(stable_rank_approx(black_box(&a256), STABLE_RANK_SKETCH));
    });
    {
        let a1k = randt(&mut Rng::new(12), 1024, 1024);
        let r = quick.run("stable_rank_approx 1024x1024", || {
            black_box(stable_rank_approx(
                black_box(&a1k),
                STABLE_RANK_SKETCH,
            ));
        });
        println!(
            "    -> O(d^2 r) path: {:.1} ms at d=1024 \
             (exact jacobi is O(d^3) per sweep)",
            r.mean_ns / 1e6
        );
    }
    quick.run("orthonormalize 256x8", || {
        let mut m = randt(&mut Rng::new(11), 256, 8);
        black_box(orthonormalize_columns(&mut m));
    });
}
