//! Wire-codec micro-benchmarks (Fig. 4/13 micro layer): bytes-on-wire and
//! encode/decode throughput for every boundary compression scheme at the
//! base config's boundary shape (4, 128, 256).

use protomodels::bench::{black_box, Bencher};
use protomodels::compress::{decode, encode, wire_bytes, Mode};
use protomodels::rng::Rng;
use protomodels::tensor::Tensor;

fn main() {
    let (b, n, d, k) = (4usize, 128usize, 256usize, 8usize);
    let ratio = d as f64 / k as f64;
    let mut rng = Rng::new(7);
    let full = Tensor::new(vec![b, n, d], rng.normal_f32_vec(b * n * d, 1.0));
    let comp = Tensor::new(vec![b, n, k], rng.normal_f32_vec(b * n * k, 1.0));
    let bench = Bencher::default();

    println!("== wire bytes per boundary tensor (b={b}, n={n}, d={d}, k={k}) ==");
    for mode in
        [Mode::Subspace, Mode::Raw, Mode::TopK, Mode::Quant, Mode::PowerLR]
    {
        let bytes = wire_bytes(mode, b, n, d, k, ratio);
        println!(
            "{:<10} {:>10} B   ({:>6.1}x vs raw)",
            mode.as_str(),
            bytes,
            wire_bytes(Mode::Raw, b, n, d, k, ratio) as f64 / bytes as f64
        );
    }

    println!("\n== encode+decode throughput ==");
    for (name, mode, t) in [
        ("subspace (dense k)", Mode::Subspace, &comp),
        ("raw (dense d)", Mode::Raw, &full),
        ("topk", Mode::TopK, &full),
        ("quant int8", Mode::Quant, &full),
    ] {
        let r = bench.run(&format!("encode+decode/{name}"), || {
            let f = encode(black_box(t), mode, ratio);
            black_box(decode(&f));
        });
        let mbps = t.wire_bytes() as f64 / (r.mean_ns * 1e-9) / 1e6;
        println!("    → {mbps:.0} MB/s of activations");
    }
}
