//! End-to-end pipeline benchmarks (Figs. 2/5 micro layer): real wall time
//! of one optimizer step (all PJRT executions + coordination) per config
//! and microbatch count, plus the simulated-vs-host time split.

use protomodels::bench::Bencher;
use protomodels::compress::Mode;
use protomodels::coordinator::{Pipeline, PipelineConfig};
use protomodels::data::{Corpus, CorpusKind};
use protomodels::manifest::Manifest;
use protomodels::netsim::{LinkSpec, Topology};
use protomodels::rng::Rng;

fn main() {
    let m = Manifest::load(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    )
    .expect("run `make artifacts`");
    let bench = Bencher::quick();

    for (config, mbs) in [("tiny", 2usize), ("tiny", 8), ("small", 4)] {
        for mode in [Mode::Subspace, Mode::Raw] {
            let h = m.config(config).unwrap().hyper.clone();
            let mut rng = Rng::new(2);
            let topo =
                Topology::uniform(h.stages, LinkSpec::internet_80m(), &mut rng);
            let pcfg = PipelineConfig {
                mode,
                microbatches: mbs,
                grassmann_interval: 0,
                total_steps: 10_000,
                ..Default::default()
            };
            let mut pipe = Pipeline::new(&m, config, topo, pcfg).unwrap();
            let corpus =
                Corpus::synthetic(CorpusKind::Wiki, h.vocab, 100_000, 3);
            // compile + warm
            pipe.train_step(|r| corpus.train_batch(h.b, h.n, r)).unwrap();
            let r = bench.run(
                &format!("train_step {config} M={mbs} {}", mode.as_str()),
                || {
                    pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))
                        .unwrap();
                },
            );
            let toks = (mbs * h.b * h.n) as f64;
            println!(
                "    → host {:.0} tok/s (real CPU) | PJRT share {:.0}%",
                toks / (r.mean_ns * 1e-9),
                100.0 * pipe.total_compute_seconds()
                    / pipe.host_seconds.max(1e-9)
            );
        }
    }
}
