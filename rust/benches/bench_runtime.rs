//! PJRT dispatch benchmarks: per-execution overhead of the runtime layer
//! (literal conversion + execute + fetch) for the smallest and a mid-size
//! stage program. The L3 target: dispatch overhead ≪ stage compute.

use protomodels::bench::{black_box, Bencher};
use protomodels::compress::Mode;
use protomodels::manifest::Manifest;
use protomodels::rng::Rng;
use protomodels::runtime::Runtime;
use protomodels::stage::{GlobalState, StageState};
use protomodels::tensor::{IntTensor, Value};

fn main() {
    let m = Manifest::load(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    )
    .expect("run `make artifacts`");
    let bench = Bencher::quick();

    for config in ["tiny", "base"] {
        let cm = m.config(config).unwrap().clone();
        let h = cm.hyper.clone();
        let mut rt = Runtime::new(&m, config).unwrap();
        let mut rng = Rng::new(1);
        let global = GlobalState::init(&cm, &mut rng);
        let st0 =
            StageState::init(&cm, 0, Mode::Subspace, &global, &mut rng)
                .unwrap();
        let tok = IntTensor::new(
            vec![h.b, h.n],
            (0..h.b * h.n).map(|i| (i % h.vocab) as i32).collect(),
        );
        let mut args: Vec<Value> =
            st0.params.iter().cloned().map(Value::F32).collect();
        args.push(Value::F32(global.u.clone()));
        args.push(Value::F32(global.t_fixed.clone()));
        args.push(Value::I32(tok));
        rt.execute("subspace/first_fwd", &args).unwrap(); // compile outside
        let r = bench.run(&format!("execute subspace/first_fwd [{config}]"), || {
            black_box(rt.execute("subspace/first_fwd", black_box(&args)).unwrap());
        });
        println!(
            "    → {:.1} µs/exec; host args: {} tensors",
            r.mean_ns / 1e3,
            args.len()
        );
    }
}
