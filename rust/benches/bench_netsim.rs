//! netsim + schedule micro-benchmarks: per-transfer sampling cost and the
//! GPipe makespan recurrence at large (stage × microbatch) grids — the
//! L3 bookkeeping that must never rival stage compute.

use protomodels::bench::{black_box, Bencher};
use protomodels::coordinator::schedule::{gpipe_makespan, StepCosts, Tx};
use protomodels::netsim::{Link, LinkSpec, Topology};
use protomodels::rng::Rng;

fn costs(p: usize, m: usize) -> StepCosts {
    StepCosts {
        stages: p,
        microbatches: m,
        fwd: vec![vec![1e-3; m]; p],
        bwd: vec![vec![3e-3; m]; p],
        tx_fwd: vec![vec![Tx { ser: 1e-4, lat: 2e-3 }; m]; p - 1],
        tx_bwd: vec![vec![Tx { ser: 1e-4, lat: 2e-3 }; m]; p - 1],
        opt: vec![1e-4; p],
        tail: 0.0,
    }
}

fn main() {
    let bench = Bencher::default();
    let mut rng = Rng::new(5);
    let mut link = Link::new(LinkSpec::internet_80m(), rng.fork(0));
    bench.run("link.sample (N(B,0.2B) draw)", || {
        black_box(link.sample(black_box(65536)));
    });

    let mut topo = Topology::global_regions(8, &mut rng);
    bench.run("topology.broadcast 8 stages", || {
        black_box(topo.broadcast(black_box(8192)));
    });

    for (p, m) in [(4usize, 8usize), (8, 32), (32, 64)] {
        let c = costs(p, m);
        bench.run(&format!("gpipe_makespan P={p} M={m}"), || {
            black_box(gpipe_makespan(black_box(&c)));
        });
    }
}
