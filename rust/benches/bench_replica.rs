//! Replicated-pipeline benchmarks: cost of the ring all-reduce
//! simulation and the hybrid makespan bookkeeping at growing replica
//! counts — L3 overhead that must stay far below stage compute.

use protomodels::bench::{black_box, Bencher};
use protomodels::compress::Mode;
use protomodels::coordinator::replica::{simulate_hybrid_step, HybridSimSpec};
use protomodels::manifest::Hyper;
use protomodels::netsim::{LinkSpec, ReplicaRing, MBPS};
use protomodels::rng::Rng;

fn hyper() -> Hyper {
    Hyper::base_sim()
}

fn main() {
    let bench = Bencher::default();
    let mut rng = Rng::new(11);

    for r in [2usize, 8, 32] {
        let mut ring = ReplicaRing::new(r, LinkSpec::internet_80m(), &mut rng);
        bench.run(&format!("ring.all_reduce R={r} 1 MB"), || {
            black_box(ring.all_reduce(black_box(1_000_000)));
        });
    }

    for r in [1usize, 4, 16] {
        let spec = HybridSimSpec::uniform(hyper(), r, 80.0 * MBPS);
        bench.run(&format!("simulate_hybrid_step R={r}"), || {
            black_box(simulate_hybrid_step(black_box(&spec)));
        });
    }

    for dp in [Mode::Subspace, Mode::Raw] {
        let mut spec = HybridSimSpec::uniform(hyper(), 8, 80.0 * MBPS);
        spec.dp_mode = dp;
        bench.run(&format!("simulate_hybrid_step R=8 dp={}", dp.as_str()), || {
            black_box(simulate_hybrid_step(black_box(&spec)));
        });
    }
}
