//! End-to-end tests of the replicated-pipeline (data-parallel ×
//! model-parallel) cost model — the analytic path, which needs neither
//! AOT artifacts nor a PJRT backend.

use protomodels::compress::{dp_wire_bytes, Mode};
use protomodels::coordinator::replica::{simulate_hybrid_step, HybridSimSpec};
use protomodels::manifest::Hyper;
use protomodels::netsim::{
    ring_allreduce_bytes_per_link, LinkSpec, ReplicaRing, MBPS,
};
use protomodels::rng::Rng;
use protomodels::timemodel::stage_param_count;

fn hyper() -> Hyper {
    Hyper::base_sim()
}

/// Deterministic link (no jitter) so the assertions are exact.
fn quiet(bw_mbps: f64, latency_s: f64) -> LinkSpec {
    LinkSpec { bandwidth_bps: bw_mbps * MBPS, latency_s, jitter_frac: 0.0 }
}

fn spec(replicas: usize, bw_mbps: f64, dp_mode: Mode) -> HybridSimSpec {
    let mut s = HybridSimSpec::uniform(hyper(), replicas, bw_mbps * MBPS);
    s.link = quiet(bw_mbps, 2e-3);
    s.ring_link = quiet(bw_mbps, 2e-3);
    s.dp_mode = dp_mode;
    s
}

#[test]
fn makespan_monotone_in_replica_count() {
    for dp_mode in [Mode::Subspace, Mode::Raw] {
        let mut prev = 0.0;
        for r in [1usize, 2, 3, 4, 6, 8] {
            let t = simulate_hybrid_step(&spec(r, 80.0, dp_mode))
                .makespan
                .total;
            assert!(
                t >= prev - 1e-12,
                "{dp_mode:?} R={r}: {t} < {prev} (makespan must be \
                 non-decreasing in R)"
            );
            prev = t;
        }
    }
}

#[test]
fn subspace_dp_beats_raw_at_consumer_bandwidth() {
    // acceptance: at 80 Mbps the dp=subspace hybrid must finish the step
    // strictly faster than dp=raw (the gradient payload is d/k smaller)
    for r in [2usize, 4, 8] {
        let sub = simulate_hybrid_step(&spec(r, 80.0, Mode::Subspace))
            .makespan
            .total;
        let raw = simulate_hybrid_step(&spec(r, 80.0, Mode::Raw))
            .makespan
            .total;
        assert!(sub < raw, "R={r}: subspace {sub} !< raw {raw}");
    }
}

#[test]
fn dp_modes_converge_at_datacenter_bandwidth() {
    // at 16 Gbps the all-reduce mostly overlaps with the pipeline drain:
    // the dp-mode gap shrinks dramatically vs consumer bandwidth
    let sub_dc = simulate_hybrid_step(&spec(4, 16_000.0, Mode::Subspace)).makespan;
    let raw_dc = simulate_hybrid_step(&spec(4, 16_000.0, Mode::Raw)).makespan;
    let raw_slow = simulate_hybrid_step(&spec(4, 80.0, Mode::Raw)).makespan;
    assert!(
        (raw_dc.total - sub_dc.total) / sub_dc.total < 0.5,
        "16 Gbps: raw {} should be close to subspace {}",
        raw_dc.total,
        sub_dc.total
    );
    assert!(
        raw_dc.tail < raw_slow.tail / 10.0,
        "raw dp tail must collapse at datacenter bandwidth: {} vs {}",
        raw_dc.tail,
        raw_slow.tail
    );
}

#[test]
fn straggler_degrades_by_predicted_factor() {
    // compute-bound, zero-latency setting: a 2x-slower replica must
    // degrade the hybrid step by ~2x (the max over replicas)
    let mut nominal = spec(4, 16_000.0, Mode::Subspace);
    nominal.link = quiet(16_000.0, 0.0);
    nominal.ring_link = quiet(16_000.0, 0.0);
    let t0 = simulate_hybrid_step(&nominal).makespan.total;
    for slow in [1.5f64, 2.0, 4.0] {
        let mut s = nominal.clone();
        s.slowdown = vec![1.0, 1.0, 1.0, slow];
        let t = simulate_hybrid_step(&s).makespan.total;
        let factor = t / t0;
        assert!(
            (factor - slow).abs() < 0.1 * slow,
            "slowdown {slow}: observed {factor}"
        );
    }
}

#[test]
fn straggler_position_is_irrelevant() {
    let mut a = spec(4, 300.0, Mode::Subspace);
    a.slowdown = vec![2.0, 1.0, 1.0, 1.0];
    let mut b = spec(4, 300.0, Mode::Subspace);
    b.slowdown = vec![1.0, 1.0, 1.0, 2.0];
    // jitter-free links: both placements see identical per-replica costs,
    // so the max over replicas is the same
    let ta = simulate_hybrid_step(&a).makespan.total;
    let tb = simulate_hybrid_step(&b).makespan.total;
    assert!((ta - tb).abs() < 1e-9, "{ta} vs {tb}");
}

#[test]
fn dp_byte_accounting_matches_closed_form() {
    let h = hyper();
    for (r, dp_mode) in [(2usize, Mode::Raw), (4, Mode::Subspace), (8, Mode::Quant)] {
        let res = simulate_hybrid_step(&spec(r, 80.0, dp_mode));
        let expect: u64 = (0..h.stages)
            .map(|s| {
                ring_allreduce_bytes_per_link(
                    r,
                    dp_wire_bytes(
                        dp_mode,
                        stage_param_count(&h, s),
                        h.d,
                        h.k,
                        h.ratio,
                    ),
                )
            })
            .sum();
        assert_eq!(res.dp_bytes_per_link, expect, "R={r} {dp_mode:?}");
    }
}

#[test]
fn ring_allreduce_time_matches_expectation_without_jitter() {
    let mut rng = Rng::new(3);
    let spec_l = quiet(80.0, 0.0);
    for r in [2usize, 4, 8] {
        let mut ring = ReplicaRing::new(r, spec_l, &mut rng);
        let bytes = 8_000_000usize;
        let expected = ring.expected_all_reduce(bytes);
        let simulated = ring.all_reduce(bytes);
        assert!(
            (simulated - expected).abs() < 1e-9,
            "R={r}: {simulated} vs {expected}"
        );
        // closed form: 2(R−1) rounds of ceil(B/R) over 10 MB/s
        let chunk = (bytes + r - 1) / r;
        let manual = 2.0 * (r - 1) as f64 * (chunk as f64 * 8.0) / (80.0 * MBPS);
        assert!((simulated - manual).abs() < 1e-9, "R={r}");
    }
}

#[test]
fn hetero_tail_interplay_is_consistent() {
    // a straggler delays gradient readiness, so the absolute comm_end
    // grows, but the *tail* (non-overlapped part) cannot grow relative to
    // a zero-compute baseline: tail <= full serial all-reduce time
    let mut s = spec(4, 80.0, Mode::Raw);
    s.slowdown = vec![1.0, 1.0, 1.0, 2.0];
    let res = simulate_hybrid_step(&s);
    assert!(res.makespan.tail >= 0.0);
    assert!(res.makespan.total >= res.makespan.compute_end);
    assert!(res.makespan.comm_end <= res.makespan.total + 1e-12);
    assert!(res.makespan.tail <= res.makespan.allreduce_busy + 1e-9);
}
