//! Property-based tests over coordinator invariants (routing, batching,
//! scheduling, codecs). The offline vendor set has no proptest crate, so
//! cases are generated with the library's own deterministic RNG — each
//! property is checked over a few hundred random instances with the
//! failing seed printed on panic.

use protomodels::compress::{
    decode, dp_wire_bytes, encode, topk_keep, wire_bytes, Mode,
};
use protomodels::coordinator::schedule::{
    gpipe_makespan, hybrid_makespan, StepCosts, Tx,
};
use protomodels::linalg::{
    matmul, matmul_nt, matmul_reference, orthonormalize_columns,
    project_rows, singular_values, stable_rank, stable_rank_approx,
    transpose, STABLE_RANK_SKETCH,
};
use protomodels::netsim::{
    ring_allreduce_bytes_per_link, Link, LinkSpec, ReplicaRing, Topology,
};
use protomodels::rng::Rng;
use protomodels::tensor::Tensor;

fn randt(rng: &mut Rng, shape: &[usize]) -> Tensor {
    Tensor::new(
        shape.to_vec(),
        rng.normal_f32_vec(shape.iter().product(), 1.0),
    )
}

#[test]
fn prop_quant_roundtrip_within_one_step() {
    // int8 symmetric quantization: every element reconstructs within
    // half a quantization step (scale = max|x| / 127), across random
    // shapes and magnitude scales spanning six decades
    for seed in 0..300u64 {
        let mut rng = Rng::new(seed ^ 0x0111);
        let shape = vec![1 + rng.below(8), 1 + rng.below(96)];
        let amp = 10f64.powf(rng.uniform() * 6.0 - 3.0) as f32;
        let mut t = randt(&mut rng, &shape);
        for x in t.data.iter_mut() {
            *x *= amp;
        }
        if seed % 17 == 0 {
            // degenerate all-zeros tensor must round-trip exactly
            t = Tensor::zeros(&shape);
        }
        let f = encode(&t, Mode::Quant, 1.0);
        let d = decode(&f);
        let step = t.max_abs() / 127.0;
        for (i, (a, b)) in t.data.iter().zip(&d.data).enumerate() {
            assert!(
                (a - b).abs() <= 0.5 * step * (1.0 + 1e-5) + f32::MIN_POSITIVE,
                "seed {seed} elem {i}: {a} -> {b} (step {step})"
            );
        }
    }
}

#[test]
fn prop_topk_exact_on_kept_zero_elsewhere() {
    // top-k: every surviving element is bitwise-exact, everything else
    // is exactly zero, at most `keep` survivors, and no dropped element
    // outweighs a kept one
    for seed in 0..300u64 {
        let mut rng = Rng::new(seed ^ 0x707B);
        let shape = vec![1 + rng.below(6), 1 + rng.below(64)];
        let ratio = [2.0, 4.0, 8.0, 16.0][rng.below(4)];
        let t = randt(&mut rng, &shape);
        let keep = topk_keep(t.numel(), ratio).min(t.numel());
        let f = encode(&t, Mode::TopK, ratio);
        let d = decode(&f);
        let mut kept = 0usize;
        let mut min_kept = f32::INFINITY;
        let mut max_dropped = 0.0f32;
        for (i, (a, b)) in t.data.iter().zip(&d.data).enumerate() {
            if *b != 0.0 {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "seed {seed} elem {i} not exact"
                );
                kept += 1;
                min_kept = min_kept.min(a.abs());
            } else {
                max_dropped = max_dropped.max(a.abs());
            }
        }
        assert!(kept <= keep, "seed {seed}: {kept} survivors > keep {keep}");
        if kept > 0 {
            assert!(
                max_dropped <= min_kept,
                "seed {seed}: dropped {max_dropped} outweighs kept {min_kept}"
            );
        }
    }
}

fn rand_costs(rng: &mut Rng) -> StepCosts {
    let p = 2 + rng.below(6);
    let m = 1 + rng.below(12);
    let r = |rng: &mut Rng| 1e-4 + rng.uniform() * 1e-2;
    StepCosts {
        stages: p,
        microbatches: m,
        fwd: (0..p).map(|_| (0..m).map(|_| r(rng)).collect()).collect(),
        bwd: (0..p).map(|_| (0..m).map(|_| r(rng)).collect()).collect(),
        tx_fwd: (0..p - 1)
            .map(|_| (0..m).map(|_| Tx { ser: r(rng), lat: r(rng) }).collect())
            .collect(),
        tx_bwd: (0..p - 1)
            .map(|_| (0..m).map(|_| Tx { ser: r(rng), lat: r(rng) }).collect())
            .collect(),
        opt: (0..p).map(|_| r(rng)).collect(),
        tail: rng.uniform() * 1e-3,
    }
}

#[test]
fn prop_makespan_bounds() {
    // total >= every per-stage serial compute; total <= fully-serial run
    for seed in 0..300u64 {
        let mut rng = Rng::new(seed);
        let c = rand_costs(&mut rng);
        let ms = gpipe_makespan(&c);
        let serial: f64 = c
            .fwd
            .iter()
            .chain(c.bwd.iter())
            .map(|v| v.iter().sum::<f64>())
            .sum::<f64>()
            + c.opt.iter().sum::<f64>()
            + c.tx_fwd
                .iter()
                .chain(c.tx_bwd.iter())
                .flat_map(|v| v.iter().map(|t| t.ser + t.lat))
                .sum::<f64>()
            + c.tail;
        // bwd[last] is unused by design: the last stage fuses fwd+bwd
        // into last_loss, whose cost lives in fwd[last]
        let per_stage_max: f64 = (0..c.stages)
            .map(|s| {
                let bwd = if s + 1 == c.stages {
                    0.0
                } else {
                    c.bwd[s].iter().sum::<f64>()
                };
                c.fwd[s].iter().sum::<f64>() + bwd + c.opt[s]
            })
            .fold(0.0, f64::max);
        assert!(
            ms.total >= per_stage_max - 1e-12,
            "seed {seed}: makespan {} < stage bound {per_stage_max}",
            ms.total
        );
        assert!(
            ms.total <= serial + 1e-9,
            "seed {seed}: makespan {} > serial {serial}",
            ms.total
        );
        assert!(ms.overhead >= -1e-9, "seed {seed}");
    }
}

#[test]
fn prop_makespan_monotone_in_costs() {
    // inflating any single cost never shrinks the makespan
    for seed in 0..150u64 {
        let mut rng = Rng::new(seed ^ 0xA5);
        let c = rand_costs(&mut rng);
        let base = gpipe_makespan(&c).total;
        let mut c2 = c.clone();
        let s = rng.below(c.stages);
        let mb = rng.below(c.microbatches);
        c2.fwd[s][mb] += 0.05;
        assert!(
            gpipe_makespan(&c2).total >= base - 1e-12,
            "seed {seed}: fwd inflation shrank makespan"
        );
        let mut c3 = c.clone();
        if c.stages > 1 {
            let l = rng.below(c.stages - 1);
            c3.tx_fwd[l][mb].ser += 0.05;
            assert!(
                gpipe_makespan(&c3).total >= base - 1e-12,
                "seed {seed}: tx inflation shrank makespan"
            );
        }
    }
}

#[test]
fn prop_topk_codec_keeps_exactly_largest() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed ^ 0x70);
        let numel = 16 + rng.below(512);
        let t = randt(&mut rng, &[numel]);
        let ratio = 2.0 + rng.uniform() * 30.0;
        let f = encode(&t, Mode::TopK, ratio);
        let d = decode(&f);
        let keep = topk_keep(numel, ratio).min(numel);
        let mut kept: Vec<f32> = Vec::new();
        let mut dropped: Vec<f32> = Vec::new();
        let mut nonzero = 0;
        for (a, b) in t.data.iter().zip(&d.data) {
            if *b != 0.0 {
                assert_eq!(a, b, "seed {seed}: kept value altered");
                kept.push(a.abs());
                nonzero += 1;
            } else if *a != 0.0 {
                dropped.push(a.abs());
            }
        }
        assert!(nonzero <= keep, "seed {seed}: kept {nonzero} > {keep}");
        if let (Some(min_kept), Some(max_dropped)) = (
            kept.iter().cloned().reduce(f32::min),
            dropped.iter().cloned().reduce(f32::max),
        ) {
            assert!(
                min_kept >= max_dropped,
                "seed {seed}: topk not magnitude-ordered"
            );
        }
    }
}

#[test]
fn prop_quant_codec_error_bound_and_size() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed ^ 0x71);
        let numel = 1 + rng.below(400);
        let t = randt(&mut rng, &[numel]);
        let f = encode(&t, Mode::Quant, 4.0);
        assert_eq!(f.wire_len(), 4 + numel);
        let d = decode(&f);
        let bound = t.max_abs() / 127.0 * 0.5 + 1e-6;
        for (a, b) in t.data.iter().zip(&d.data) {
            assert!(
                (a - b).abs() <= bound,
                "seed {seed}: quant err {} > {bound}",
                (a - b).abs()
            );
        }
    }
}

#[test]
fn prop_wire_bytes_ordering() {
    // subspace <= every lossy scheme <= raw, at matched ratio d/k
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed ^ 0x72);
        let b = 1 + rng.below(8);
        let n = 8 * (1 + rng.below(32));
        let d = 32 * (1 + rng.below(16));
        let k = 1 + rng.below(d / 4);
        let ratio = d as f64 / k as f64;
        let sub = wire_bytes(Mode::Subspace, b, n, d, k, ratio);
        let raw = wire_bytes(Mode::Raw, b, n, d, k, ratio);
        assert!(sub <= raw, "seed {seed}");
        for m in [Mode::TopK, Mode::Quant, Mode::PowerLR] {
            let w = wire_bytes(m, b, n, d, k, ratio);
            assert!(w <= raw + 8, "seed {seed}: {m:?} {w} > raw {raw}");
        }
        assert_eq!(raw / sub, d / k, "seed {seed}");
    }
}

#[test]
fn prop_projection_idempotent_and_contractive() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed ^ 0x73);
        let d = 8 + rng.below(48);
        let k = 1 + rng.below(d / 2);
        let mut u = randt(&mut rng, &[d, k]);
        if !orthonormalize_columns(&mut u) {
            continue;
        }
        let rows = 4 + rng.below(32);
        let w = randt(&mut rng, &[rows, d]);
        let p1 = project_rows(&w, &u);
        let p2 = project_rows(&p1, &u);
        let diff = p1
            .data
            .iter()
            .zip(&p2.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-3, "seed {seed}: projection not idempotent");
        assert!(
            p1.frobenius_norm() <= w.frobenius_norm() * (1.0 + 1e-4),
            "seed {seed}: projection expanded"
        );
        assert!(
            stable_rank(&p1) <= k as f64 + 0.5,
            "seed {seed}: stable rank above k"
        );
    }
}

#[test]
fn prop_svd_invariants() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed ^ 0x74);
        let m = 4 + rng.below(24);
        let n = 4 + rng.below(24);
        let a = randt(&mut rng, &[m, n]);
        let sv = singular_values(&a);
        assert_eq!(sv.len(), m.min(n));
        for w in sv.windows(2) {
            assert!(w[0] >= w[1] - 1e-4, "seed {seed}: not sorted");
        }
        assert!(sv.iter().all(|s| *s >= 0.0));
        let fro2: f64 = a.data.iter().map(|x| (*x as f64).powi(2)).sum();
        let sv2: f64 = sv.iter().map(|s| (*s as f64).powi(2)).sum();
        assert!((fro2 - sv2).abs() / fro2.max(1e-9) < 1e-3, "seed {seed}");
        let svt = singular_values(&transpose(&a));
        for (x, y) in sv.iter().zip(&svt) {
            assert!((x - y).abs() < 1e-2 * (1.0 + x.abs()), "seed {seed}");
        }
    }
}

#[test]
fn prop_orthonormal_basis_roundtrip() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed ^ 0x75);
        let d = 8 + rng.below(40);
        let k = 1 + rng.below(d / 2);
        let mut u = randt(&mut rng, &[d, k]);
        if !orthonormalize_columns(&mut u) {
            continue;
        }
        let coef = randt(&mut rng, &[1, k]);
        let v = matmul(&coef, &transpose(&u));
        let back = matmul(&matmul(&v, &u), &transpose(&u));
        for (a, b) in v.data.iter().zip(&back.data) {
            assert!((a - b).abs() < 1e-3, "seed {seed}");
        }
    }
}

#[test]
fn prop_link_transfer_positive_and_monotone_mean() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0x76);
        let bw = 1e6 + rng.uniform() * 1e9;
        let mut link = Link::new(LinkSpec::new(bw, 1e-3), rng.fork(1));
        let reps = 200;
        let small: f64 = (0..reps).map(|_| link.transfer_time(1_000)).sum();
        let big: f64 =
            (0..reps).map(|_| link.transfer_time(1_000_000)).sum();
        assert!(small > 0.0 && big > small, "seed {seed}");
    }
}

#[test]
fn prop_dp_subspace_never_exceeds_raw() {
    // the ISSUE's dp-mode property: subspace (U-only) gradient payloads
    // never exceed raw, for any parameter count / dims / ratio
    for seed in 0..300u64 {
        let mut rng = Rng::new(seed ^ 0x78);
        let d = 8 * (1 + rng.below(128));
        let k = 1 + rng.below(d);
        let elems = 1 + rng.below(4_000_000);
        let ratio = 1.0 + rng.uniform() * 63.0;
        let sub = dp_wire_bytes(Mode::Subspace, elems, d, k, ratio);
        let raw = dp_wire_bytes(Mode::Raw, elems, d, k, ratio);
        assert!(
            sub <= raw,
            "seed {seed}: dp subspace {sub} > raw {raw} (d={d} k={k})"
        );
        // and the nofixed ablation prices identically
        assert_eq!(sub, dp_wire_bytes(Mode::NoFixed, elems, d, k, ratio));
    }
}

#[test]
fn prop_ring_allreduce_accounting_and_monotonicity() {
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed ^ 0x79);
        let r = 2 + rng.below(15);
        let bytes = 1 + rng.below(10_000_000);
        let mut ring =
            ReplicaRing::new(r, LinkSpec::internet_80m(), &mut rng.fork(1));
        let t = ring.all_reduce(bytes);
        assert!(t > 0.0, "seed {seed}");
        let per_link = ring_allreduce_bytes_per_link(r, bytes);
        for l in &ring.links {
            assert_eq!(l.bytes_sent, per_link, "seed {seed}");
        }
        // per-link traffic approaches 2B as R grows and never exceeds it
        assert!(per_link <= 2 * bytes as u64 + 2 * r as u64, "seed {seed}");
        assert!(per_link >= bytes as u64, "seed {seed}: R>=2 moves >= B");
    }
}

#[test]
fn prop_hybrid_makespan_invariants() {
    // total >= compute_end; tail >= 0; total <= compute_end + serial comm
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed ^ 0x7A);
        let replicas = 1 + rng.below(6);
        let mut makespans = Vec::new();
        for _ in 0..replicas {
            let c = rand_costs(&mut rng);
            makespans.push(gpipe_makespan(&c));
        }
        let stages = makespans[0].grad_ready.len();
        let payloads: Vec<usize> =
            (0..stages).map(|_| 1 + rng.below(1_000_000)).collect();
        let mut ring = ReplicaRing::new(
            replicas,
            LinkSpec::internet_80m(),
            &mut rng.fork(2),
        );
        let h = hybrid_makespan(&makespans, &payloads, &mut ring);
        let compute_end =
            makespans.iter().map(|m| m.total).fold(0.0, f64::max);
        assert!(
            (h.compute_end - compute_end).abs() < 1e-12,
            "seed {seed}"
        );
        assert!(h.total >= compute_end - 1e-12, "seed {seed}");
        assert!(h.tail >= -1e-12, "seed {seed}");
        assert!(
            h.total <= compute_end + h.allreduce_busy + 1e-9,
            "seed {seed}: total {} > compute {} + busy {}",
            h.total,
            compute_end,
            h.allreduce_busy
        );
        if replicas == 1 {
            assert_eq!(h.tail, 0.0, "seed {seed}: R=1 must be comm-free");
        }
    }
}

#[test]
fn prop_tiled_matmul_bitwise_equals_reference() {
    // the tiled/threaded kernel keeps the naive per-element accumulation
    // order, so it must agree *bitwise* on arbitrary (tile-straddling)
    // shapes — the foundation of the grid determinism contract
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0x7B);
        let m = 1 + rng.below(90);
        let k = 1 + rng.below(300);
        let n = 1 + rng.below(300);
        let a = randt(&mut rng, &[m, k]);
        let b = randt(&mut rng, &[k, n]);
        let tiled = matmul(&a, &b);
        let naive = matmul_reference(&a, &b);
        assert_eq!(
            tiled.data, naive.data,
            "seed {seed}: ({m}x{k}x{n}) tiled != reference"
        );
        // fused A·Bᵀ agrees with the transpose composition the same way
        let bt = randt(&mut rng, &[n, k]);
        let fused = matmul_nt(&a, &bt);
        let composed = matmul(&a, &transpose(&bt));
        assert_eq!(fused.data, composed.data, "seed {seed}: nt mismatch");
    }
}

#[test]
fn prop_stable_rank_approx_within_tolerance() {
    // randomized estimator vs exact Jacobi, over random shapes/spectra:
    // the ISSUE's 2% contract, with fallback-to-exact as the safety net
    for seed in 0..15u64 {
        let mut rng = Rng::new(seed ^ 0x7C);
        let m = 16 + rng.below(48);
        let n = 16 + rng.below(48);
        let a = randt(&mut rng, &[m, n]);
        let exact = stable_rank(&a);
        let approx = stable_rank_approx(&a, STABLE_RANK_SKETCH);
        assert!(
            (approx - exact).abs() <= 0.02 * exact.max(1e-12),
            "seed {seed}: ({m}x{n}) approx {approx} vs exact {exact}"
        );
    }
}

#[test]
fn prop_topology_accounting_exact() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0x77);
        let stages = 2 + rng.below(10);
        let mut topo =
            Topology::uniform(stages, LinkSpec::internet_80m(), &mut rng);
        let mut expect = 0u64;
        for _ in 0..50 {
            let link = rng.below(stages - 1);
            let bytes = 1 + rng.below(100_000);
            topo.send(link, bytes);
            expect += bytes as u64;
        }
        assert_eq!(topo.total_bytes(), expect, "seed {seed}");
    }
}

#[test]
fn prop_wire_frames_bit_transparent_for_every_codec() {
    // the framed transport must be a bit-transparent carrier: for every
    // codec (lossless or lossy), wrapping the codec payload in a wire
    // frame, serializing, and re-parsing yields the identical payload
    // bytes — and decoding the re-framed payload is bitwise-identical
    // to decoding the original codec frame
    use protomodels::transport::{FrameKind, WireFrame, HEADER_LEN};
    for seed in 0..120u64 {
        let mut rng = Rng::new(seed ^ 0x77AE);
        let rows = 1 + rng.below(12);
        let cols = 1 + rng.below(48);
        let t = randt(&mut rng, &[rows, cols]);
        let ratio = 1.5 + rng.uniform() * 14.0;
        for mode in [
            Mode::Subspace,
            Mode::Raw,
            Mode::TopK,
            Mode::Quant,
            Mode::PowerLR,
            Mode::NoFixed,
            Mode::RawBf16,
            Mode::SubspaceBf16,
        ] {
            let f = encode(&t, mode, ratio);
            let kind = if seed % 2 == 0 {
                FrameKind::Fwd
            } else {
                FrameKind::Bwd
            };
            let wf = WireFrame::boundary(
                kind,
                mode,
                seed,
                (seed % 7) as usize,
                f.payload.clone(),
            );
            let bytes = wf.to_bytes();
            assert_eq!(bytes.len(), HEADER_LEN + f.payload.len());
            let parsed =
                WireFrame::read_from(&mut std::io::Cursor::new(bytes))
                    .unwrap();
            assert_eq!(parsed.kind, kind);
            assert_eq!(parsed.codec, Some(mode), "seed {seed} {mode:?}");
            assert_eq!(parsed.step, seed);
            assert_eq!(parsed.payload, f.payload, "seed {seed} {mode:?}");
            let back = protomodels::compress::Frame {
                mode,
                shape: t.shape.clone(),
                payload: parsed.payload,
            };
            let a = decode(&f);
            let b = decode(&back);
            assert_eq!(a.shape, b.shape);
            for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "seed {seed} {mode:?} elem {i}"
                );
            }
        }
    }
}

#[test]
fn prop_every_prefix_of_a_frame_stream_parses_or_classifies_the_cut() {
    // chaos-harness framing property: for EVERY prefix length of a valid
    // multi-frame stream (liveness kinds included), the reader must (a)
    // recover each fully-contained frame bit-exactly, resuming at the
    // right offset after each one, and (b) classify the cut position of
    // the first incomplete frame — clean shutdown exactly at a frame
    // boundary vs a link severed mid-header vs mid-payload. No prefix
    // may panic or allocate past the declared payload length.
    use protomodels::transport::{FrameKind, WireFrame, HEADER_LEN};
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed ^ 0x5EAF);
        // a plausible session: handshake, boundary traffic, liveness
        // beacons, a checkpoint, a recovery order, goodbye — with
        // randomized payload sizes (zero-length control payloads too)
        let frames = vec![
            WireFrame::control(
                FrameKind::Hello,
                0,
                rng.normal_f32_vec(8, 1.0).iter().map(|x| *x as u8).collect(),
            ),
            WireFrame::boundary(
                FrameKind::Fwd,
                Mode::Subspace,
                seed,
                0,
                vec![0xF0; 1 + rng.below(96)],
            ),
            WireFrame::control(FrameKind::Heartbeat, seed, vec![0xB1; 16]),
            WireFrame::boundary(
                FrameKind::Bwd,
                Mode::Raw,
                seed,
                1,
                vec![0x0B; 1 + rng.below(64)],
            ),
            WireFrame::control(
                FrameKind::Checkpoint,
                seed + 1,
                vec![0xCC; 32 + rng.below(128)],
            ),
            WireFrame::control(FrameKind::StepEnd, seed + 1, vec![]),
            WireFrame::control(
                FrameKind::Reassign,
                seed + 2,
                vec![0x12; 25 + rng.below(40)],
            ),
            WireFrame::grad(
                FrameKind::GradRing,
                Mode::Quant,
                seed + 2,
                (seed % 5) as usize,
                vec![0x6A; 4 + rng.below(80)],
            ),
            WireFrame::grad(
                FrameKind::GradGossip,
                Mode::Raw,
                seed + 2,
                0,
                vec![0x60; 4 * (1 + rng.below(24))],
            ),
            WireFrame::control(FrameKind::Bye, seed + 2, vec![]),
        ];
        let mut stream = Vec::new();
        for f in &frames {
            stream.extend_from_slice(&f.to_bytes());
        }
        for cut in 0..=stream.len() {
            let mut cur = std::io::Cursor::new(&stream[..cut]);
            let mut offset = 0usize;
            let mut parsed = 0usize;
            // every frame wholly inside the prefix parses bit-exactly
            while parsed < frames.len()
                && offset + frames[parsed].wire_len() <= cut
            {
                let got = WireFrame::read_from(&mut cur)
                    .unwrap_or_else(|e| {
                        panic!("seed {seed} cut {cut} frame {parsed}: {e}")
                    });
                assert_eq!(
                    got, frames[parsed],
                    "seed {seed} cut {cut} frame {parsed}"
                );
                offset += frames[parsed].wire_len();
                parsed += 1;
            }
            // …and the next read classifies where the stream ended
            let err = WireFrame::read_from(&mut cur)
                .expect_err("truncated stream must not yield a frame")
                .to_string();
            let rem = cut - offset;
            assert!(
                err.contains("departed"),
                "seed {seed} cut {cut}: every cut is a departure: {err}"
            );
            if rem == 0 {
                assert!(
                    err.contains("frame boundary") && !err.contains("severed"),
                    "seed {seed} cut {cut}: clean shutdown misreported: {err}"
                );
            } else if rem < HEADER_LEN {
                assert!(
                    err.contains("severed mid-header"),
                    "seed {seed} cut {cut} (rem {rem}): {err}"
                );
            } else {
                assert!(
                    err.contains("severed mid-payload"),
                    "seed {seed} cut {cut} (rem {rem}): {err}"
                );
            }
        }
    }
}

#[test]
fn prop_grad_frame_payloads_roundtrip_for_every_dp_codec() {
    // gradient frames on the dp wire: for every dp codec and random
    // gradient, (a) the encoded payload is EXACTLY the dp_wire_bytes
    // pricing, (b) framing as GradRing/GradGossip and re-parsing is
    // bit-transparent, and (c) decoding the re-framed payload matches
    // decoding the original bytes bitwise
    use protomodels::transport::dp::{decode_grad, encode_grad};
    use protomodels::transport::{FrameKind, WireFrame};
    let (d, k) = (32usize, 4usize);
    for seed in 0..80u64 {
        let mut rng = Rng::new(seed ^ 0x6A0D);
        let n = 8 + rng.below(300);
        let xs = rng.normal_f32_vec(n, 1.0);
        let ratio = 1.5 + rng.uniform() * 10.0;
        for mode in [
            Mode::Raw,
            Mode::RawBf16,
            Mode::Quant,
            Mode::TopK,
            Mode::Subspace,
            Mode::NoFixed,
            Mode::SubspaceBf16,
        ] {
            let payload = encode_grad(mode, &xs, d, k, ratio).unwrap();
            assert_eq!(
                payload.len(),
                dp_wire_bytes(mode, n, d, k, ratio),
                "seed {seed} {mode:?}: payload must price exactly"
            );
            let kind = if seed % 2 == 0 {
                FrameKind::GradRing
            } else {
                FrameKind::GradGossip
            };
            let wf = WireFrame::grad(
                kind,
                mode,
                seed,
                (seed % 4) as usize,
                payload.clone(),
            );
            let parsed =
                WireFrame::read_from(&mut std::io::Cursor::new(wf.to_bytes()))
                    .unwrap();
            assert_eq!(parsed.kind, kind);
            assert_eq!(parsed.codec, Some(mode));
            assert_eq!(parsed.payload, payload, "seed {seed} {mode:?}");
            let a = decode_grad(mode, &payload, n, d, k, ratio).unwrap();
            let b = decode_grad(mode, &parsed.payload, n, d, k, ratio).unwrap();
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "seed {seed} {mode:?} elem {i}"
                );
            }
            // a truncated payload must be rejected, not misdecoded
            if !payload.is_empty() {
                assert!(decode_grad(
                    mode,
                    &payload[..payload.len() - 1],
                    n,
                    d,
                    k,
                    ratio
                )
                .is_err());
            }
        }
    }
}

#[test]
fn prop_mode_fromstr_display_roundtrip_is_exhaustive() {
    // Mode's FromStr/Display pair must round-trip every variant (the
    // exhaustive Mode::ALL sweep — adding a variant without wiring both
    // impls fails here), agree with wire_tag's numbering, and reject
    // unknown or near-miss labels instead of guessing
    use std::collections::HashSet;
    let mut seen_labels = HashSet::new();
    let mut seen_tags = HashSet::new();
    for m in Mode::ALL {
        let label = m.to_string();
        assert_eq!(label, m.as_str());
        assert!(seen_labels.insert(label.clone()), "duplicate {label}");
        let back: Mode = label.parse().unwrap();
        assert_eq!(back, m, "{label} must round-trip");
        assert!(seen_tags.insert(m.wire_tag()), "duplicate tag for {label}");
        assert_eq!(Mode::from_wire_tag(m.wire_tag()), Some(m));
        // labels are canonical: case and whitespace variants are errors
        assert!(label.to_uppercase().parse::<Mode>().is_err());
        assert!(format!(" {label}").parse::<Mode>().is_err());
    }
    assert_eq!(seen_labels.len(), Mode::ALL.len());
    for bad in ["", "sub", "raw16", "bf16", "gossip", "none"] {
        assert!(bad.parse::<Mode>().is_err(), "{bad:?} must not parse");
    }
}

#[test]
fn prop_trace_json_roundtrip_is_exact() {
    // Chrome trace_event serialization is lossless: parsing the JSON
    // text of a randomly generated trace rebuilds the identical event
    // list, clock tag, and canonical span multiset. Float args avoid
    // integral values (integral non-negative numbers canonicalize to
    // Arg::U by design); timestamps exercise both integral-microsecond
    // and fractional values, which Display round-trips exactly.
    use protomodels::obs::trace::{Arg, Clock, Trace, TraceEvent};
    let cats = ["compute", "frame", "codec", "reduce", "sim"];
    let names = ["fwd", "bwd", "send:fwd", "recv:bwd", "step", "gossip"];
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed ^ 0x0B5E);
        let clock = if rng.below(2) == 0 {
            Clock::Host
        } else {
            Clock::Virtual
        };
        let n = rng.below(12);
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            let instant = rng.below(4) == 0;
            let mut args = Vec::new();
            if rng.below(2) == 0 {
                args.push((
                    "bytes".to_string(),
                    Arg::U(rng.next_u64() % 1_000_000_000_000),
                ));
            }
            if rng.below(3) == 0 {
                args.push((
                    "peer".to_string(),
                    Arg::S(format!("127.0.0.1:{}", 9000 + rng.below(999))),
                ));
            }
            if rng.below(3) == 0 {
                // .5 fraction keeps the value non-integral so it stays
                // an Arg::F through the canonical re-parse
                args.push((
                    "ratio".to_string(),
                    Arg::F(rng.below(1000) as f64 + 0.5),
                ));
            }
            events.push(TraceEvent {
                cat: cats[rng.below(cats.len())].to_string(),
                name: names[rng.below(names.len())].to_string(),
                pid: rng.below(8) as u32,
                tid: rng.below(8) as u32,
                ts_us: rng.uniform() * 1e9,
                dur_us: if instant {
                    0.0
                } else {
                    rng.below(1_000_000) as f64
                },
                instant,
                args,
            });
        }
        let trace = Trace { events, clock };
        let text = trace.to_json().to_string();
        let back = Trace::parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: reparse failed: {e:#}"));
        assert_eq!(back, trace, "seed {seed}: round trip not exact");
        assert_eq!(
            back.canonical_lines(),
            trace.canonical_lines(),
            "seed {seed}: canonical form drifted through JSON"
        );
    }
}
