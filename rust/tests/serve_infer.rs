//! Distributed decode-serving contracts (DESIGN.md §16).
//!
//! The flagship parity claim: the staged decode pipeline produces
//! **bitwise-identical token streams** single-process, over in-process
//! channels, and over real TCP sockets — for *every* boundary codec —
//! and a session's stream is invariant to continuous-batching width
//! (who shares its batch, when it's admitted, when neighbors evict),
//! because boundary rows are encoded per session, never packed across
//! the batch. Wire and KV accounting must match the `memory::` analytic
//! models exactly.

use protomodels::compress::Mode;
use protomodels::data::CorpusKind;
use protomodels::manifest::Hyper;
use protomodels::memory;
use protomodels::transport::{
    handshake_wrap, run_serve_local, serve_infer, ServeReport, ServeSpec,
    TrafficSpec, TrainSpec, TransportKind, Workload,
};

fn spec(mode: Mode, max_batch: usize) -> ServeSpec {
    ServeSpec::builder(Hyper::tiny_native())
        .mode(mode)
        .steps(400)
        .seed(23)
        .corpus(CorpusKind::Wiki, 6_000)
        .traffic(TrafficSpec {
            sessions: 4,
            mean_gap: 1.2,
            prompt: (2, 5),
            gen: (2, 4),
        })
        .max_batch(max_batch)
        .build()
        .unwrap()
}

fn token_streams(r: &ServeReport) -> Vec<(u32, Vec<u32>)> {
    r.sessions.iter().map(|s| (s.id, s.tokens.clone())).collect()
}

#[test]
fn every_codec_decodes_identically_over_channel_and_tcp() {
    for mode in Mode::ALL {
        let sp = spec(mode, 2);
        let local = run_serve_local(&sp).unwrap();
        let chan = serve_infer(&sp, TransportKind::Channel).unwrap();
        let tcp = serve_infer(&sp, TransportKind::Tcp).unwrap();
        assert_eq!(
            token_streams(&local),
            token_streams(&chan),
            "{mode}: channel run diverged from single-process"
        );
        assert_eq!(
            token_streams(&local),
            token_streams(&tcp),
            "{mode}: tcp run diverged from single-process"
        );
        assert_eq!(local.steps, chan.steps, "{mode}");
        assert_eq!(local.steps, tcp.steps, "{mode}");
        assert_eq!(local.tokens_generated, tcp.tokens_generated, "{mode}");
        for s in &local.sessions {
            assert_eq!(
                s.tokens.len(),
                s.gen,
                "{mode}: session {} missed its budget",
                s.id
            );
            let vocab = sp.core.h.vocab as u32;
            assert!(s.tokens.iter().all(|&t| t < vocab), "{mode}");
        }
    }
}

#[test]
fn batching_width_cannot_perturb_any_codecs_stream() {
    // widths 1..4 change who shares a batch with whom at every step
    // (and therefore every admission/eviction boundary); per-session
    // boundary encoding guarantees the streams cannot feel it
    for mode in Mode::ALL {
        let base = run_serve_local(&spec(mode, 1)).unwrap();
        for width in [2usize, 3, 4] {
            let wide = run_serve_local(&spec(mode, width)).unwrap();
            assert_eq!(
                token_streams(&base),
                token_streams(&wide),
                "{mode}: width {width} perturbed a session stream"
            );
        }
    }
}

#[test]
fn wire_and_kv_accounting_match_the_analytic_models() {
    // max_batch 1 keeps exactly one session active per executed step,
    // so every frame on every link prices at the width-1 analytic model
    let mut sp = spec(Mode::Subspace, 1);
    sp.traffic.sessions = 2;
    let h = sp.core.h.clone();
    let rep = run_serve_local(&sp).unwrap();
    let links = (h.stages - 1) as u64;
    let per_decode =
        memory::decode_frame_bytes(&h, Mode::Subspace, 1) as u64;
    let per_token = memory::token_frame_bytes(1) as u64;
    assert_eq!(rep.frames, rep.steps * links * 2);
    assert_eq!(
        rep.wire_bytes,
        rep.steps * links * (per_decode + per_token)
    );
    // peak KV residency = the analytic per-position model at the
    // longest session's final position (one session resident at a time)
    let maxpos = rep
        .sessions
        .iter()
        .map(|s| s.prompt_len + s.gen - 1)
        .max()
        .unwrap();
    assert_eq!(rep.kv_peak_bytes, memory::kv_cache_bytes(&h, maxpos));
}

#[test]
fn serve_and_train_handshakes_are_byte_incompatible() {
    let sp = spec(Mode::Subspace, 2);
    let serve = sp.handshake_digest();
    assert!(serve.starts_with(b"PMCFG3"));
    let train = handshake_wrap(
        &TrainSpec::from_worker(sp.core.clone()).digest(),
        Workload::Train,
    );
    assert!(train.starts_with(b"PMCFG3"));
    // same model, same codec, same seed — but a train worker must never
    // complete a handshake with a serving stage
    assert_ne!(serve, train);
    // the serving axis is load-bearing material, not a suffix tag only:
    // changing max_batch changes the digest
    let mut other = spec(Mode::Subspace, 3);
    other.traffic = sp.traffic.clone();
    assert_ne!(serve, other.handshake_digest());
}

#[test]
fn exhausted_budget_and_bad_specs_fail_descriptively() {
    let mut sp = spec(Mode::Subspace, 2);
    sp.core.steps = 2;
    sp.core.cfg.total_steps = 2;
    let err = run_serve_local(&sp).unwrap_err().to_string();
    assert!(err.contains("raise --steps"), "{err}");

    let mut sp = spec(Mode::Subspace, 2);
    sp.traffic.prompt = (30, 30);
    sp.traffic.gen = (30, 30);
    let err = sp.validate().unwrap_err().to_string();
    assert!(err.contains("KV capacity") || err.contains("n ="), "{err}");
}
