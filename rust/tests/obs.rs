//! The observability layer's contracts (DESIGN.md §15): span traces
//! are deterministic across pool widths (identical canonical span
//! multisets at any `--threads`), frame byte counters absorbed from a
//! trace equal both the transports' own send accounting and the
//! `memory::transport_frame_bytes` wire model exactly, and a recorded
//! trace survives a file round trip and replays through the event
//! engine via `obs::diff`. Artifact-free; every test that records
//! takes the process-wide session lock through `TraceSession`, so the
//! suite is safe under the default parallel test runner.

use protomodels::compress::{wire_bytes, Mode};
use protomodels::coordinator::PipelineConfig;
use protomodels::data::CorpusKind;
use protomodels::manifest::Hyper;
use protomodels::memory;
use protomodels::nn::Optim;
use protomodels::obs::counters::RunMetrics;
use protomodels::obs::diff::diff_trace;
use protomodels::obs::trace::{Clock, Trace, TraceSession};
use protomodels::par;
use protomodels::sim::Schedule;
use protomodels::transport::{run_local, TransportKind, WorkerSpec};

fn spec(steps: usize, stages: usize, microbatches: usize) -> WorkerSpec {
    let mut h = Hyper::tiny_native();
    h.stages = stages;
    h.layers = h.blocks_per_stage * stages;
    WorkerSpec {
        h,
        cfg: PipelineConfig {
            mode: Mode::Subspace,
            microbatches,
            grassmann_interval: 0,
            lr: 1e-2,
            warmup_steps: 3,
            total_steps: steps,
            seed: 7,
            ..Default::default()
        },
        optim: Optim::AdamW,
        steps,
        corpus_kind: CorpusKind::Wiki,
        corpus_tokens: 60_000,
    }
}

/// Record one channel-distributed run and return (trace, loss curve).
fn traced_run(s: &WorkerSpec) -> (Trace, Vec<f64>) {
    let session = TraceSession::start(Clock::Host);
    let rep = run_local(s, TransportKind::Channel).expect("channel run");
    (session.stop(), rep.losses)
}

#[test]
fn canonical_span_set_is_pool_width_invariant() {
    let s = spec(3, 2, 2);
    let saved = par::max_threads_setting();
    par::set_max_threads(1);
    let (t1, l1) = traced_run(&s);
    par::set_max_threads(8);
    let (t8, l8) = traced_run(&s);
    par::set_max_threads(saved);
    assert!(!t1.events.is_empty(), "traced run recorded no spans");
    assert_eq!(
        t1.canonical_lines(),
        t8.canonical_lines(),
        "canonical span multiset differs between pool widths 1 and 8"
    );
    for (a, b) in l1.iter().zip(&l8) {
        assert_eq!(a.to_bits(), b.to_bits(), "loss curve depends on pool width");
    }
}

#[test]
fn frame_byte_counters_match_wire_accounting_and_memory_model() {
    let s = spec(3, 2, 2);
    let session = TraceSession::start(Clock::Host);
    let rep = run_local(&s, TransportKind::Channel).expect("channel run");
    let trace = session.stop();
    let mut m = RunMetrics::new();
    m.absorb_trace(&trace);

    // sender-side wire bytes from the trace equal the transports' own
    // bytes_sent() accounting exactly
    assert_eq!(m.counter("bytes.wire"), rep.wire_bytes);

    // every boundary frame carries exactly the payload the analytic
    // wire model prices: memory::transport_frame_bytes = header +
    // compress::wire_bytes
    let h = &s.h;
    let per_frame = memory::transport_frame_bytes(h, s.cfg.mode) as u64;
    let per_payload =
        wire_bytes(s.cfg.mode, h.b, h.n, h.d, h.k, h.ratio) as u64;
    let p = h.stages as u64;
    let mb = s.cfg.microbatches as u64;
    let steps = s.steps as u64;
    let expect_frames = (p - 1) * mb * steps;
    assert_eq!(m.counter("frames.sent.fwd"), expect_frames);
    assert_eq!(m.counter("frames.sent.bwd"), expect_frames);
    assert_eq!(m.counter("bytes.wire.fwd"), expect_frames * per_frame);
    assert_eq!(m.counter("bytes.wire.bwd"), expect_frames * per_frame);
    assert_eq!(m.counter("bytes.payload.fwd"), expect_frames * per_payload);
    assert_eq!(m.counter("bytes.payload.bwd"), expect_frames * per_payload);

    // send and recv frame counts agree per kind on a clean run
    for kind in ["fwd", "bwd", "step-end", "hello"] {
        assert_eq!(
            m.counter(&format!("frames.sent.{kind}")),
            m.counter(&format!("frames.recv.{kind}")),
            "frame kind {kind} lost in flight"
        );
    }
}

#[test]
fn trace_survives_file_round_trip_and_diffs_against_engine() {
    let s = spec(3, 2, 4);
    let (trace, _) = traced_run(&s);
    let dir = std::env::temp_dir().join("protomodels_obs_test");
    let path = dir.join("trace.json");
    trace.write_file(&path).expect("write trace");
    let back = Trace::read_file(&path).expect("read trace");
    assert_eq!(back, trace);
    // the perfetto wrapper fields are present in the file
    let text = std::fs::read_to_string(&path).expect("trace text");
    assert!(text.contains("\"traceEvents\""));
    assert!(text.contains("\"displayTimeUnit\""));
    let report = diff_trace(&back, Schedule::Gpipe).expect("diff");
    assert!(report.steps > 0, "no complete steps replayed");
    assert!(
        report.max_rel_err.is_finite(),
        "non-finite placement error"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn metrics_written_from_trace_parse_back() {
    let s = spec(2, 2, 2);
    let (trace, _) = traced_run(&s);
    let mut m = RunMetrics::new();
    m.absorb_trace(&trace);
    let dir = std::env::temp_dir().join("protomodels_obs_metrics_test");
    let path = dir.join("METRICS.json");
    std::fs::create_dir_all(&dir).expect("mkdir");
    m.write_file(&path).expect("write metrics");
    let back = RunMetrics::parse(
        &std::fs::read_to_string(&path).expect("metrics text"),
    )
    .expect("parse metrics");
    assert_eq!(back.counter("frames.sent"), m.counter("frames.sent"));
    assert_eq!(back.counter("bytes.wire"), m.counter("bytes.wire"));
    std::fs::remove_dir_all(&dir).ok();
}
