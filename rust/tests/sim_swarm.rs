//! Acceptance gates of the discrete-event swarm simulator (ISSUE 3):
//!
//! - **Parity contract** — on zero-jitter homogeneous configs, the
//!   event-driven `SimReport` reproduces the closed-form
//!   `hybrid_makespan` within 1e-6 relative, across a grid of
//!   (stages, replicas, compression modes).
//! - **Churn edge cases** — a leave landing mid-all-reduce aborts and
//!   restarts the reduce on the re-routed ring; zero-bandwidth links
//!   are a validation error, not an infinite event time.
//!
//! (Queue-level edge cases — empty queue, simultaneous-event
//! tie-breaks — live in `sim::queue`'s unit tests; exact GPipe
//! engine-vs-recurrence parity on arbitrary jittered costs lives in
//! `sim::step`'s.)

use protomodels::compress::Mode;
use protomodels::coordinator::replica::{simulate_hybrid_step, HybridSimSpec};
use protomodels::manifest::Hyper;
use protomodels::netsim::{LinkSpec, MBPS};
use protomodels::sim::{
    simulate_swarm, ChurnEvent, ChurnKind, ChurnSpec, SwarmSpec,
};

fn quiet(bw_mbps: f64) -> LinkSpec {
    LinkSpec { bandwidth_bps: bw_mbps * MBPS, latency_s: 2e-3, jitter_frac: 0.0 }
}

fn hyper_with_stages(stages: usize) -> Hyper {
    let mut h = Hyper::base_sim();
    h.stages = stages;
    h
}

#[test]
fn parity_swarm_matches_hybrid_makespan_on_quiet_grid() {
    let mut worst: f64 = 0.0;
    for stages in [2usize, 3, 4, 6] {
        for replicas in [1usize, 2, 4] {
            for dp_mode in [Mode::Subspace, Mode::Raw, Mode::Quant] {
                let h = hyper_with_stages(stages);

                let mut swarm = SwarmSpec::uniform(h.clone(), replicas, 80.0 * MBPS);
                swarm.link = quiet(80.0);
                swarm.ring_link = quiet(80.0);
                swarm.dp_mode = dp_mode;
                let rep = simulate_swarm(&swarm).unwrap();

                let mut hybrid =
                    HybridSimSpec::uniform(h, replicas, 80.0 * MBPS);
                hybrid.link = quiet(80.0);
                hybrid.ring_link = quiet(80.0);
                hybrid.dp_mode = dp_mode;
                let reference = simulate_hybrid_step(&hybrid).makespan;

                let rel = (rep.total - reference.total).abs()
                    / reference.total.max(1e-12);
                worst = worst.max(rel);
                assert!(
                    rel < 1e-6,
                    "parity broken at stages={stages} R={replicas} \
                     dp={dp_mode:?}: sim {} vs analytic {} (rel {rel:.3e})",
                    rep.total,
                    reference.total
                );
                // the HybridMakespan-mirroring fields agree too
                let rel_c = (rep.compute_end - reference.compute_end).abs()
                    / reference.compute_end.max(1e-12);
                assert!(rel_c < 1e-6, "compute_end diverged ({rel_c:.3e})");
                assert!(
                    (rep.tail - reference.tail).abs()
                        <= 1e-6 * reference.total.max(1.0),
                    "tail diverged: {} vs {}",
                    rep.tail,
                    reference.tail
                );
            }
        }
    }
    eprintln!("parity grid worst relative deviation: {worst:.3e}");
}

#[test]
fn leave_mid_allreduce_restarts_on_rerouted_ring() {
    let mut spec = SwarmSpec::uniform(Hyper::base_sim(), 4, 80.0 * MBPS);
    spec.link = quiet(80.0);
    spec.ring_link = quiet(80.0);
    let base = simulate_swarm(&spec).unwrap();
    // the all-reduce phase spans (compute overlap aside) up to comm_end;
    // aim a scripted leave squarely inside it
    assert!(base.comm_end > base.compute_end, "expected a comm-bound step");
    let t_inside = 0.5 * (base.compute_end + base.comm_end);

    let mut churned = spec.clone();
    churned.churn = ChurnSpec::Scripted(vec![ChurnEvent {
        time: t_inside,
        replica: 1,
        kind: ChurnKind::Leave,
    }]);
    let rep = simulate_swarm(&churned).unwrap();
    assert_eq!(rep.leaves, 1);
    assert_eq!(
        rep.allreduce_restarts, 1,
        "the in-flight all-reduce must abort and restart"
    );
    // the aborted rounds count as ring-busy waste on top of real work
    assert!(rep.allreduce_busy > 0.0);
    // the re-routed 3-member ring still completes the step
    assert!(rep.total > 0.0 && rep.total.is_finite());
}

#[test]
fn zero_bandwidth_rejected_before_simulation() {
    let mut spec = SwarmSpec::uniform(Hyper::base_sim(), 2, 80.0 * MBPS);
    spec.link.bandwidth_bps = 0.0;
    let err = simulate_swarm(&spec).unwrap_err().to_string();
    assert!(err.contains("bandwidth"), "unexpected error: {err}");

    let mut spec = SwarmSpec::uniform(Hyper::base_sim(), 2, 80.0 * MBPS);
    spec.ring_link.bandwidth_bps = -1.0;
    assert!(simulate_swarm(&spec).is_err());
}

#[test]
fn jitter_widens_step_times_but_stays_reproducible() {
    let mut spec = SwarmSpec::uniform(Hyper::base_sim(), 2, 80.0 * MBPS);
    spec.steps = 5;
    spec.link.jitter_frac = 0.2;
    spec.ring_link.jitter_frac = 0.2;
    spec.lat_jitter_frac = 0.2;
    let a = simulate_swarm(&spec).unwrap();
    let b = simulate_swarm(&spec).unwrap();
    assert_eq!(a.step_seconds, b.step_seconds, "same spec, same trace");
    // jittered steps are not all identical
    let min = a.step_seconds.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = a.step_seconds.iter().cloned().fold(0.0f64, f64::max);
    assert!(max > min, "jitter produced perfectly uniform steps: {a:?}");
    // a different seed gives a different (still valid) trace
    let mut other = spec.clone();
    other.seed ^= 0xBEEF;
    let c = simulate_swarm(&other).unwrap();
    assert_ne!(a.step_seconds, c.step_seconds);
}
