//! End-to-end integration: the rust coordinator driving real PJRT
//! executions of the AOT artifacts (tiny config).
//!
//! These tests need both the AOT artifacts (`make artifacts`) and a real
//! PJRT backend (not the offline `xla` stub); they self-skip otherwise
//! via the `gate!` macro so `cargo test` stays green everywhere.

use protomodels::compress::Mode;
use protomodels::coordinator::{Pipeline, PipelineConfig};
use protomodels::data::{Corpus, CorpusKind};
use protomodels::manifest::Manifest;
use protomodels::netsim::{LinkSpec, Topology};
use protomodels::rng::Rng;
use protomodels::runtime::Runtime;
use protomodels::timemodel::TimeModel;

fn can_execute() -> bool {
    let have_artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists();
    if !have_artifacts {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return false;
    }
    if !Runtime::backend_available() {
        eprintln!("skipping: offline xla stub linked (no PJRT backend)");
        return false;
    }
    true
}

macro_rules! gate {
    () => {
        if !can_execute() {
            return;
        }
    };
}

fn manifest() -> Manifest {
    Manifest::load(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    )
    .expect("run `make artifacts` first")
}

fn mk_pipeline(mode: Mode, grassmann: usize, seed: u64) -> (Pipeline, Corpus) {
    let m = manifest();
    let h = m.config("tiny").unwrap().hyper.clone();
    let mut rng = Rng::new(seed);
    let topo = Topology::uniform(h.stages, LinkSpec::internet_80m(), &mut rng);
    let cfg = PipelineConfig {
        mode,
        microbatches: 2,
        grassmann_interval: grassmann,
        lr: 3e-3,
        warmup_steps: 5,
        total_steps: 200,
        time_model: TimeModel::default_analytic(),
        seed,
        ..Default::default()
    };
    let pipe = Pipeline::new(&m, "tiny", topo, cfg).unwrap();
    let corpus = Corpus::synthetic(CorpusKind::Wiki, h.vocab, 100_000, seed);
    (pipe, corpus)
}

#[test]
fn subspace_training_reduces_loss() {
    gate!();
    let (mut pipe, corpus) = mk_pipeline(Mode::Subspace, 0, 1);
    let h = pipe.hyper();
    let mut first = None;
    let mut last = 0.0;
    for step in 0..40 {
        let stats = pipe
            .train_step(|r| corpus.train_batch(h.b, h.n, r))
            .unwrap();
        assert!(stats.loss.is_finite(), "step {step} loss {}", stats.loss);
        if first.is_none() {
            first = Some(stats.loss);
        }
        last = stats.loss;
    }
    let first = first.unwrap();
    assert!(
        last < first - 0.3,
        "loss should drop: first {first:.4} last {last:.4}"
    );
}

#[test]
fn subspace_closure_maintained_through_training() {
    gate!();
    let (mut pipe, corpus) = mk_pipeline(Mode::Subspace, 0, 2);
    let h = pipe.hyper();
    for _ in 0..10 {
        pipe.train_step(|r| corpus.train_batch(h.b, h.n, r)).unwrap();
    }
    let leak = pipe.subspace_leak();
    assert!(leak < 1e-4, "constrained weights left S: leak {leak}");
}

#[test]
fn raw_training_reduces_loss_and_costs_more_wire() {
    gate!();
    let (mut pipe_raw, corpus) = mk_pipeline(Mode::Raw, 0, 3);
    let (mut pipe_sub, _) = mk_pipeline(Mode::Subspace, 0, 3);
    let h = pipe_raw.hyper();
    let raw = pipe_raw
        .train_step(|r| corpus.train_batch(h.b, h.n, r))
        .unwrap();
    let sub = pipe_sub
        .train_step(|r| corpus.train_batch(h.b, h.n, r))
        .unwrap();
    assert!(raw.loss.is_finite() && sub.loss.is_finite());
    let ratio = raw.wire_bytes as f64 / sub.wire_bytes as f64;
    let expect = h.d as f64 / h.k as f64;
    assert!(
        (ratio - expect).abs() < 0.01,
        "wire ratio {ratio} != d/k {expect}"
    );
    // simulated time over 80 Mbps: raw must be slower even at tiny scale
    // (the dramatic paper-scale gap is asserted by the base-config
    // convergence experiment, where payloads dwarf latency)
    assert!(
        raw.sim_seconds > 1.15 * sub.sim_seconds,
        "raw {} vs sub {}",
        raw.sim_seconds,
        sub.sim_seconds
    );
}

#[test]
fn grassmann_update_executes_and_preserves_closure() {
    gate!();
    let (mut pipe, corpus) = mk_pipeline(Mode::Subspace, 3, 4);
    let h = pipe.hyper();
    let u_before = pipe.global.u.clone();
    for _ in 0..4 {
        pipe.train_step(|r| corpus.train_batch(h.b, h.n, r)).unwrap();
    }
    // U must have moved at step 3, and weights re-projected onto new S
    let moved: f32 = pipe
        .global
        .u
        .data
        .iter()
        .zip(&u_before.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(moved > 1e-7, "U never updated");
    assert!(pipe.subspace_leak() < 1e-4);
    // U stays orthonormal
    let u = &pipe.global.u;
    let g = protomodels::linalg::matmul(
        &protomodels::linalg::transpose(u),
        u,
    );
    for i in 0..h.k {
        for j in 0..h.k {
            let want = if i == j { 1.0 } else { 0.0 };
            assert!((g.at2(i, j) - want).abs() < 1e-3);
        }
    }
}

#[test]
fn eval_and_inference_paths_work() {
    gate!();
    let (mut pipe, corpus) = mk_pipeline(Mode::Subspace, 0, 5);
    let h = pipe.hyper();
    let loss = pipe.eval(3, |r| corpus.val_batch(h.b, h.n, r)).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    let (secs, toks) = pipe
        .forward_throughput(4, |r| corpus.val_batch(h.b, h.n, r))
        .unwrap();
    assert!(secs > 0.0);
    assert_eq!(toks, 4 * h.b * h.n);
}

#[test]
fn lossy_modes_run_end_to_end() {
    gate!();
    for mode in [Mode::TopK, Mode::Quant, Mode::PowerLR] {
        let (mut pipe, corpus) = mk_pipeline(mode, 0, 6);
        let h = pipe.hyper();
        let stats = pipe
            .train_step(|r| corpus.train_batch(h.b, h.n, r))
            .unwrap();
        assert!(
            stats.loss.is_finite(),
            "{mode:?} produced non-finite loss"
        );
    }
}

#[test]
fn replicated_pipelines_train_and_account_dp_bytes() {
    gate!();
    use protomodels::coordinator::replica::{ReplicaConfig, ReplicaSet};
    use protomodels::netsim::ReplicaRing;
    let m = manifest();
    let h = m.config("tiny").unwrap().hyper.clone();
    let mut rng = Rng::new(21);
    let replicas = 2usize;
    let topos: Vec<Topology> = (0..replicas)
        .map(|_| {
            Topology::uniform(h.stages, LinkSpec::internet_80m(), &mut rng)
        })
        .collect();
    let ring = ReplicaRing::new(replicas, LinkSpec::internet_80m(), &mut rng);
    let cfg = PipelineConfig {
        mode: Mode::Subspace,
        microbatches: 2,
        grassmann_interval: 0,
        lr: 3e-3,
        warmup_steps: 5,
        total_steps: 20,
        time_model: TimeModel::default_analytic(),
        seed: 21,
        ..Default::default()
    };
    let mut set = ReplicaSet::new(
        &m,
        "tiny",
        topos,
        ring,
        cfg,
        ReplicaConfig { dp_mode: Mode::Subspace, slowdown: vec![1.0, 2.0] },
    )
    .unwrap();
    let corpus = Corpus::synthetic(CorpusKind::Wiki, h.vocab, 100_000, 21);
    let s = set
        .train_step(|r| corpus.train_batch(h.b, h.n, r))
        .unwrap();
    assert!(s.loss.is_finite());
    assert!(s.dp_bytes > 0, "gradient all-reduce must move bytes");
    assert!(s.sim_seconds >= s.makespan.compute_end);
    assert_eq!(s.tokens, replicas * 2 * h.b * h.n);
    // replicas hold identical (averaged) parameters afterwards
    let p0 = &set.pipelines[0].stages[0].params[0];
    let p1 = &set.pipelines[1].stages[0].params[0];
    assert_eq!(p0.data, p1.data);
}

#[test]
fn deterministic_given_seed() {
    gate!();
    let run = |seed| {
        let (mut pipe, corpus) = mk_pipeline(Mode::Subspace, 0, seed);
        let h = pipe.hyper();
        let mut losses = vec![];
        for _ in 0..3 {
            losses.push(
                pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))
                    .unwrap()
                    .loss,
            );
        }
        losses
    };
    assert_eq!(run(7), run(7));
    assert_ne!(run(7), run(8));
}
