//! Determinism contract of the parallel experiment engine (DESIGN.md §8):
//! grid drivers must emit byte-identical CSVs at `--threads 1` and
//! `--threads N`, `par::map` must preserve submission order under any
//! pool size, and per-cell seeds must be independent of pool width.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use protomodels::exp::{self, ExpOpts};
use protomodels::par;
use protomodels::runtime::Runtime;

fn scratch(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join("protomodels_par_determinism")
        .join(name)
}

/// Every file under `dir`, as relative-path → bytes (recursive).
fn dir_bytes(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        let entries = match std::fs::read_dir(dir) {
            Ok(e) => e,
            Err(_) => return,
        };
        for entry in entries.flatten() {
            let p = entry.path();
            if p.is_dir() {
                walk(root, &p, out);
            } else {
                let rel = p
                    .strip_prefix(root)
                    .unwrap()
                    .to_string_lossy()
                    .into_owned();
                out.insert(rel, std::fs::read(&p).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(dir, dir, &mut out);
    out
}

/// Artifact-dependent runs need both the AOT manifest and a real PJRT
/// backend; without them the artifact-gated tests self-skip (the same
/// policy as the rest of the suite).
fn have_artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    if !Runtime::backend_available() {
        eprintln!("skipping: no PJRT backend linked");
        return None;
    }
    Some(dir)
}

/// Parallel pool width to compare against the serial run. CI's
/// determinism matrix sets `PROTOMODELS_TEST_POOL` to {1, 2, 8};
/// locally it defaults to 4.
fn pool_width() -> usize {
    std::env::var("PROTOMODELS_TEST_POOL")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|n| *n >= 1)
        .unwrap_or(4)
}

/// Run experiment `name` twice (1 worker vs `pool_width()`) into
/// sibling dirs and return the two output trees.
fn run_twice(
    name: &str,
    artifacts: Option<&Path>,
    sub: &str,
) -> (BTreeMap<String, Vec<u8>>, BTreeMap<String, Vec<u8>>) {
    let base = scratch(sub);
    let _ = std::fs::remove_dir_all(&base);
    let mut trees = Vec::new();
    // distinct dirs per *run* (not per width): the width-1 matrix leg
    // compares two independent serial runs — a reproducibility check —
    // instead of silently diffing one directory against itself
    for (run, threads) in [(0usize, 1usize), (1, pool_width())] {
        let out_dir = base.join(format!("run{run}_t{threads}"));
        let mut opts = ExpOpts {
            out_dir: out_dir.clone(),
            fast: true,
            threads,
            ..Default::default()
        };
        if let Some(a) = artifacts {
            opts.artifacts = a.to_path_buf();
        }
        exp::run(name, &opts).unwrap();
        trees.push(dir_bytes(&out_dir));
    }
    let b = trees.pop().unwrap();
    let a = trees.pop().unwrap();
    (a, b)
}

#[test]
fn dp_grid_csvs_identical_across_pool_sizes() {
    let (serial, parallel) = run_twice("dp-grid", None, "dp_grid");
    assert!(
        serial.contains_key("fig_dp_grid.csv"),
        "dp-grid wrote no CSV: {:?}",
        serial.keys().collect::<Vec<_>>()
    );
    assert_eq!(
        serial, parallel,
        "dp-grid output differs between --threads 1 and --threads 4"
    );
    // sanity: the grid actually has content (header + fast-preset cells)
    let csv = String::from_utf8(serial["fig_dp_grid.csv"].clone()).unwrap();
    assert!(csv.lines().count() > 20, "suspiciously small grid:\n{csv}");
}

#[test]
fn sim_grid_csvs_identical_across_pool_sizes() {
    // the discrete-event simulator grid is artifact-free: the full
    // byte-determinism contract applies unconditionally
    let (serial, parallel) = run_twice("sim-grid", None, "sim_grid");
    assert!(
        serial.contains_key("fig_sim_grid.csv"),
        "sim-grid wrote no CSV: {:?}",
        serial.keys().collect::<Vec<_>>()
    );
    assert_eq!(
        serial, parallel,
        "sim-grid output differs between --threads 1 and --threads N"
    );
    let csv = String::from_utf8(serial["fig_sim_grid.csv"].clone()).unwrap();
    assert!(csv.lines().count() > 10, "suspiciously small grid:\n{csv}");
    // every zero-jitter GPipe cell carries a parity column ~0
    for line in csv.lines().skip(1) {
        let cols: Vec<&str> = line.split(',').collect();
        if cols[0] == "gpipe" && cols[3] == "0" {
            let parity: f64 = cols.last().unwrap().parse().unwrap();
            assert!(parity < 1e-6, "parity column too large: {line}");
        }
    }
}

#[test]
fn convergence_native_csvs_identical_across_pool_sizes() {
    // the native autodiff backend trains real models inside pool cells,
    // and the tape itself now runs data-parallel (backward matmul rows
    // split across a nested worker-kernel budget, DESIGN.md §13) — the
    // kernels stay thread-count bit-stable, so the full training
    // curves — not just summary rows — must be byte-identical at any
    // pool width
    let (serial, parallel) =
        run_twice("convergence-native", None, "convergence_native");
    assert!(
        serial.contains_key("fig_native_convergence.csv"),
        "convergence-native wrote no summary CSV: {:?}",
        serial.keys().collect::<Vec<_>>()
    );
    assert!(
        serial
            .keys()
            .any(|k| k.starts_with("fig_native_convergence/")),
        "convergence-native wrote no per-mode curves"
    );
    assert_eq!(
        serial, parallel,
        "convergence-native output differs between --threads 1 and N"
    );
    // sanity: the summary rows carry real losses, not placeholders
    let csv =
        String::from_utf8(serial["fig_native_convergence.csv"].clone())
            .unwrap();
    for line in csv.lines().skip(1) {
        let cols: Vec<&str> = line.split(',').collect();
        let loss: f64 = cols[1].parse().unwrap();
        assert!(loss.is_finite() && loss > 0.0, "bad loss row: {line}");
    }
}

#[test]
fn churn_sweep_csvs_identical_across_pool_sizes() {
    let (serial, parallel) = run_twice("churn-sweep", None, "churn_sweep");
    assert!(serial.contains_key("fig_churn_sweep.csv"));
    assert_eq!(
        serial, parallel,
        "churn-sweep output differs between --threads 1 and --threads N"
    );
}

#[test]
fn table2_outputs_identical_across_pool_sizes() {
    let artifacts = match have_artifacts() {
        Some(a) => a,
        None => return,
    };
    let (serial, parallel) = run_twice("table2", Some(&artifacts), "table2");
    assert!(serial.contains_key("table2_compute_optimal.csv"));
    assert_eq!(
        serial, parallel,
        "table2 output differs between --threads 1 and --threads 4"
    );
}

#[test]
fn memory_tables_identical_across_pool_sizes() {
    // serial drivers must also be insensitive to the threads knob
    let (serial, parallel) = run_twice("memory-seqlen", None, "memory");
    assert_eq!(serial, parallel);
}

#[test]
fn prop_map_preserves_order_with_uneven_cells() {
    // cells of wildly different cost: order must still be submission
    // order for every pool size
    use protomodels::rng::Rng;
    let mut rng = Rng::new(0xC0FFEE);
    let items: Vec<usize> =
        (0..64).map(|_| rng.below(2000)).collect();
    let serial: Vec<u64> = items
        .iter()
        .enumerate()
        .map(|(i, n)| spin(i, *n))
        .collect();
    for threads in [2usize, 3, 5, 8] {
        let got = par::map(threads, &items, |i, n| spin(i, *n));
        assert_eq!(got, serial, "threads={threads}");
    }
}

/// A deterministic unevenly-sized unit of work.
fn spin(i: usize, n: usize) -> u64 {
    let mut acc = i as u64;
    for k in 0..n as u64 {
        acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
    }
    acc
}

#[test]
fn prop_cell_seeds_stable_under_pool_changes() {
    // the seed of cell i is a pure function of (master, i): running the
    // derivation inside pools of different widths changes nothing
    let idx: Vec<usize> = (0..40).collect();
    let direct: Vec<u64> =
        idx.iter().map(|i| par::cell_seed(99, *i)).collect();
    for threads in [1usize, 4, 7] {
        let pooled =
            par::map(threads, &idx, |_, i| par::cell_seed(99, *i));
        assert_eq!(pooled, direct, "threads={threads}");
    }
    // and distinct cells get distinct streams
    let mut uniq = direct.clone();
    uniq.sort_unstable();
    uniq.dedup();
    assert_eq!(uniq.len(), direct.len());
}
