//! Integration tests of the native autodiff backend: real training on
//! the tiny config, end to end through the pipeline, the boundary
//! codecs, the optimizer closure rules, and the coordinator's Backend
//! facade. Entirely artifact-free (no manifest, no PJRT).

use protomodels::compress::{wire_bytes, Mode};
use protomodels::coordinator::{Backend, BackendKind, PipelineConfig};
use protomodels::data::{Corpus, CorpusKind};
use protomodels::manifest::Hyper;
use protomodels::netsim::{LinkSpec, Topology};
use protomodels::nn::{NativePipeline, Optim};
use protomodels::rng::Rng;

fn pipe_for(
    mode: Mode,
    seed: u64,
    steps: usize,
    grassmann: usize,
) -> NativePipeline {
    let h = Hyper::tiny_native();
    let mut rng = Rng::new(seed);
    let topo =
        Topology::uniform(h.stages, LinkSpec::internet_80m(), &mut rng);
    let pcfg = PipelineConfig {
        mode,
        microbatches: 2,
        grassmann_interval: grassmann,
        lr: 1e-2,
        warmup_steps: 3,
        total_steps: steps,
        seed,
        ..Default::default()
    };
    NativePipeline::new(h, topo, pcfg, Optim::AdamW).unwrap()
}

fn corpus() -> Corpus {
    Corpus::synthetic(CorpusKind::Wiki, Hyper::tiny_native().vocab, 60_000, 5)
}

#[test]
fn native_training_reduces_loss() {
    let h = Hyper::tiny_native();
    let c = corpus();
    let mut pipe = pipe_for(Mode::Subspace, 17, 12, 0);
    let mut losses = Vec::new();
    for _ in 0..12 {
        let s = pipe.train_step(|r| c.train_batch(h.b, h.n, r)).unwrap();
        assert!(s.loss.is_finite(), "loss diverged: {}", s.loss);
        assert!(s.sim_seconds > 0.0);
        losses.push(s.loss);
    }
    let first = losses[0];
    let tail = losses[9..].iter().sum::<f64>() / 3.0;
    // port-measured drop ≈ 0.36 after 12 steps; 0.2 leaves ~2x headroom
    assert!(
        tail < first - 0.2,
        "no learning: first {first:.4}, last-3 mean {tail:.4}"
    );
    let val = pipe.eval(2, |r| c.val_batch(h.b, h.n, r)).unwrap();
    assert!(val.is_finite() && val > 0.0);
}

#[test]
fn native_runs_are_bitwise_reproducible() {
    let h = Hyper::tiny_native();
    let c = corpus();
    let run = |seed: u64| -> Vec<f64> {
        let mut pipe = pipe_for(Mode::Subspace, seed, 3, 0);
        (0..3)
            .map(|_| {
                pipe.train_step(|r| c.train_batch(h.b, h.n, r))
                    .unwrap()
                    .loss
            })
            .collect()
    };
    let a = run(17);
    let b = run(17);
    assert_eq!(a, b, "same seed must reproduce losses bit for bit");
    let c2 = run(18);
    assert_ne!(a, c2, "different seeds must diverge");
}

#[test]
fn subspace_closure_holds_during_training() {
    // constrained rows stay in S through optimizer steps AND through a
    // Grassmann subspace update + re-projection
    let h = Hyper::tiny_native();
    let c = corpus();
    let mut pipe = pipe_for(Mode::Subspace, 7, 6, 3);
    for step in 0..6 {
        pipe.train_step(|r| c.train_batch(h.b, h.n, r)).unwrap();
        let leak = pipe.subspace_leak();
        assert!(leak < 1e-4, "step {step}: leak {leak:.3e}");
    }
    assert!(pipe.clock > 0.0);
}

#[test]
fn backend_facade_drives_native_pipeline() {
    let h = Hyper::tiny_native();
    let c = corpus();
    let mut backend = Backend::Native(Box::new(pipe_for(Mode::Raw, 3, 2, 0)));
    assert_eq!(backend.kind(), BackendKind::Native);
    assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
    assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
    assert!(BackendKind::parse("tpu").is_err());
    let s1 = backend
        .train_step(|r| c.train_batch(h.b, h.n, r))
        .unwrap();
    let s2 = backend
        .train_step(|r| c.train_batch(h.b, h.n, r))
        .unwrap();
    assert_eq!(s2.step, 2);
    assert!(s1.loss.is_finite() && s2.loss.is_finite());
    assert!(backend.clock() > 0.0);
    let val = backend.eval(1, |r| c.val_batch(h.b, h.n, r)).unwrap();
    assert!(val.is_finite());
}

#[test]
fn boundary_bytes_deliver_the_claimed_compression() {
    let h = Hyper::tiny_native();
    let c = corpus();
    let mut sub = pipe_for(Mode::Subspace, 9, 1, 0);
    let mut raw = pipe_for(Mode::Raw, 9, 1, 0);
    let rb = raw.boundary_bytes();
    let sb = sub.boundary_bytes();
    assert!(
        rb as f64 / sb as f64 >= 10.0,
        "compression {rb}/{sb} below the 10x bar"
    );
    // StepStats wire bytes = microbatches × 2 directions × (stages−1)
    // boundaries × payload
    let m = 2 * 2 * (h.stages - 1);
    let s = sub.train_step(|r| c.train_batch(h.b, h.n, r)).unwrap();
    assert_eq!(s.wire_bytes, (m * sb) as u64);
    let r = raw.train_step(|r| c.train_batch(h.b, h.n, r)).unwrap();
    assert_eq!(r.wire_bytes, (m * rb) as u64);
    // and the accounting matches the analytic wire model
    assert_eq!(sb, wire_bytes(Mode::Subspace, h.b, h.n, h.d, h.k, h.ratio));
    assert_eq!(rb, wire_bytes(Mode::Raw, h.b, h.n, h.d, h.k, h.ratio));
}

#[test]
fn every_mode_trains_one_finite_step() {
    let h = Hyper::tiny_native();
    let c = corpus();
    for mode in [
        Mode::Subspace,
        Mode::Raw,
        Mode::TopK,
        Mode::Quant,
        Mode::PowerLR,
        Mode::NoFixed,
        Mode::RawBf16,
        Mode::SubspaceBf16,
    ] {
        let mut pipe = pipe_for(mode, 21, 1, 0);
        let s = pipe.train_step(|r| c.train_batch(h.b, h.n, r)).unwrap();
        assert!(
            s.loss.is_finite() && s.loss > 0.0,
            "{mode:?} loss {}",
            s.loss
        );
    }
}

#[test]
fn sgd_also_trains_and_keeps_closure() {
    let h = Hyper::tiny_native();
    let c = corpus();
    let mut rng = Rng::new(4);
    let topo =
        Topology::uniform(h.stages, LinkSpec::internet_80m(), &mut rng);
    let pcfg = PipelineConfig {
        mode: Mode::Subspace,
        microbatches: 2,
        grassmann_interval: 0,
        lr: 0.1,
        warmup_steps: 2,
        total_steps: 8,
        seed: 4,
        ..Default::default()
    };
    let mut pipe = NativePipeline::new(
        h.clone(),
        topo,
        pcfg,
        Optim::Sgd { momentum: 0.9 },
    )
    .unwrap();
    let mut first = 0.0;
    let mut last = 0.0;
    for i in 0..8 {
        let s = pipe.train_step(|r| c.train_batch(h.b, h.n, r)).unwrap();
        if i == 0 {
            first = s.loss;
        }
        last = s.loss;
    }
    assert!(last < first, "sgd did not learn: {first:.4} -> {last:.4}");
    assert!(pipe.subspace_leak() < 1e-4);
}

#[test]
fn checkpoint_restore_resumes_bitwise() {
    use protomodels::compress::CkptCodec;
    let h = Hyper::tiny_native();
    let c = corpus();
    // reference: 6 uninterrupted steps (Grassmann cadence exercises the
    // s_acc/s_count round-trip across the checkpoint boundary)
    let mut full = pipe_for(Mode::Subspace, 23, 6, 2);
    let full_losses: Vec<f64> = (0..6)
        .map(|_| {
            full.train_step(|r| c.train_batch(h.b, h.n, r)).unwrap().loss
        })
        .collect();
    // interrupted: 3 steps, checkpoint, resume in a FRESH pipeline
    let mut head = pipe_for(Mode::Subspace, 23, 6, 2);
    let head_losses: Vec<f64> = (0..3)
        .map(|_| {
            head.train_step(|r| c.train_batch(h.b, h.n, r)).unwrap().loss
        })
        .collect();
    assert_eq!(head_losses[..], full_losses[..3]);
    let blobs = head.checkpoint(CkptCodec::Raw);
    assert_eq!(blobs.len(), h.stages);
    // every blob is priced exactly by the memory model
    for (s, b) in blobs.iter().enumerate() {
        assert_eq!(
            b.len(),
            protomodels::memory::checkpoint_payload_bytes(
                &h,
                s,
                Mode::Subspace,
                CkptCodec::Raw,
                s == h.stages - 1,
            ),
            "stage {s} blob length off the cost model"
        );
    }
    let mut tail = pipe_for(Mode::Subspace, 23, 6, 2);
    tail.restore(&blobs, 3).unwrap();
    let tail_losses: Vec<f64> = (0..3)
        .map(|_| {
            tail.train_step(|r| c.train_batch(h.b, h.n, r)).unwrap().loss
        })
        .collect();
    assert_eq!(
        tail_losses[..],
        full_losses[3..],
        "resumed training must be bitwise the uninterrupted run"
    );
    // the RNG stream cannot rewind
    let err = head.restore(&blobs, 2).unwrap_err().to_string();
    assert!(err.contains("rewind"), "{err}");
    // blob count must match the pipeline
    let mut fresh = pipe_for(Mode::Subspace, 23, 6, 2);
    assert!(fresh.restore(&blobs[..1], 3).is_err());
}
