//! Finite-difference gradient checker for every native-backend op.
//!
//! For each tape op, over randomized shapes and seeds: build a scalar
//! probe `L = Σ C ⊙ f(x)` (C a fixed random cotangent; f the op), get
//! the tape's reverse-mode gradients, and compare input elements (all of
//! them, or a random sample for big inputs) against central differences.
//! The probe reduction accumulates in f64 so the check measures the op's
//! gradient, not the reduction's rounding.
//!
//! Robustness: every element is probed at two step sizes (ε and ε/2).
//! If the two estimates disagree, the loss is locally non-smooth there
//! (a ReLU kink crossed by the perturbation) or drowned in f32 noise —
//! the element is skipped rather than asserted, and the test separately
//! bounds the skip fraction so a broken backward rule cannot hide behind
//! wholesale skipping.
//!
//! A final end-to-end case checks a full micro pipeline stage
//! (`nn::model::build_stage`, subspace mode) — boundary projection pair
//! included — against finite differences through the composed graph.

use protomodels::compress::Mode;
use protomodels::manifest::Hyper;
use protomodels::nn::model::{build_stage, high_rank_e, sinusoidal_pe, StageIo};
use protomodels::nn::{AttnDims, Tape, Var};
use protomodels::rng::Rng;
use protomodels::stage::{GlobalState, StageState};
use protomodels::tensor::{IntTensor, Tensor};

fn randt(rng: &mut Rng, shape: &[usize], std: f32) -> Tensor {
    Tensor::new(
        shape.to_vec(),
        rng.normal_f32_vec(shape.iter().product(), std),
    )
}

/// Relative-plus-absolute tolerance check.
fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= rtol * a.abs().max(b.abs()) + atol
}

/// Central difference of `probe` at two step sizes; `Some(grad)` when
/// the estimates agree (locally smooth), `None` otherwise.
fn two_scale_fd(
    probe: &dyn Fn(f32) -> f64,
    eps: f32,
    atol: f64,
) -> Option<f64> {
    let full =
        (probe(eps) - probe(-eps)) / (2.0 * eps as f64);
    let half =
        (probe(eps / 2.0) - probe(-eps / 2.0)) / (eps as f64);
    if close(full, half, 5e-2, atol) {
        Some(half)
    } else {
        None
    }
}

/// Check the tape gradient of every input of `build` against central
/// differences. `build` constructs the graph from leaves (same order as
/// `inputs`) and returns the output node.
fn check_op<F>(
    name: &str,
    seed: u64,
    inputs: &[Tensor],
    build: F,
    eps: f32,
    rtol: f64,
    atol: f64,
) where
    F: Fn(&mut Tape, &[Var]) -> Var,
{
    // analytic pass
    let mut tape = Tape::new();
    let vars: Vec<Var> =
        inputs.iter().map(|t| tape.leaf(t.clone(), true)).collect();
    let out = build(&mut tape, &vars);
    let out_shape = tape.value(out).shape.clone();
    let mut crng = Rng::new(seed ^ 0xC07A);
    let cot = if out_shape.is_empty() {
        Tensor::scalar(1.0)
    } else {
        randt(&mut crng, &out_shape, 1.0)
    };
    tape.backward_from(out, cot.clone());
    let analytic: Vec<Tensor> = vars
        .iter()
        .map(|v| {
            tape.grad(*v)
                .cloned()
                .unwrap_or_else(|| Tensor::zeros(&tape.value(*v).shape))
        })
        .collect();

    // f64 probe loss of a fresh forward pass
    let loss = |xs: &[Tensor]| -> f64 {
        let mut t = Tape::new();
        let vs: Vec<Var> =
            xs.iter().map(|x| t.leaf(x.clone(), true)).collect();
        let o = build(&mut t, &vs);
        t.value(o)
            .data
            .iter()
            .zip(&cot.data)
            .map(|(a, c)| *a as f64 * *c as f64)
            .sum()
    };

    let mut irng = Rng::new(seed ^ 0x1D);
    let (mut checked, mut skipped) = (0usize, 0usize);
    for (wi, x) in inputs.iter().enumerate() {
        let idxs: Vec<usize> = if x.numel() <= 64 {
            (0..x.numel()).collect()
        } else {
            (0..48).map(|_| irng.below(x.numel())).collect()
        };
        for idx in idxs {
            let probe = |delta: f32| -> f64 {
                let mut xs = inputs.to_vec();
                xs[wi].data[idx] += delta;
                loss(&xs)
            };
            let Some(fd) = two_scale_fd(&probe, eps, atol) else {
                skipped += 1;
                continue;
            };
            checked += 1;
            let an = analytic[wi].data[idx] as f64;
            assert!(
                close(fd, an, rtol, atol),
                "{name} seed {seed}: input {wi} elem {idx}: fd {fd:.6e} vs \
                 tape {an:.6e}"
            );
        }
    }
    assert!(
        skipped * 3 <= checked,
        "{name} seed {seed}: {skipped} skipped vs {checked} checked — \
         too non-smooth to trust"
    );
}

#[test]
fn gradcheck_matmul() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed);
        let (m, k, n) =
            (2 + rng.below(6), 2 + rng.below(6), 2 + rng.below(6));
        let inputs =
            vec![randt(&mut rng, &[m, k], 1.0), randt(&mut rng, &[k, n], 1.0)];
        check_op(
            "matmul",
            seed,
            &inputs,
            |t, v| t.matmul(v[0], v[1]),
            1e-2,
            1e-3,
            1e-4,
        );
    }
}

#[test]
fn gradcheck_matmul_nt() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed ^ 0x20);
        let (m, k, n) =
            (2 + rng.below(6), 2 + rng.below(6), 2 + rng.below(6));
        let inputs =
            vec![randt(&mut rng, &[m, k], 1.0), randt(&mut rng, &[n, k], 1.0)];
        check_op(
            "matmul_nt",
            seed,
            &inputs,
            |t, v| t.matmul_nt(v[0], v[1]),
            1e-2,
            1e-3,
            1e-4,
        );
    }
}

#[test]
fn gradcheck_add_sub() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed ^ 0x30);
        let shape = [1 + rng.below(5), 1 + rng.below(8)];
        let inputs = vec![
            randt(&mut rng, &shape, 1.0),
            randt(&mut rng, &shape, 1.0),
        ];
        check_op(
            "add",
            seed,
            &inputs,
            |t, v| t.add(v[0], v[1]),
            1e-2,
            1e-3,
            1e-5,
        );
        check_op(
            "sub",
            seed,
            &inputs,
            |t, v| t.sub(v[0], v[1]),
            1e-2,
            1e-3,
            1e-5,
        );
    }
}

#[test]
fn gradcheck_relu() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed ^ 0x40);
        let shape = [2 + rng.below(4), 2 + rng.below(8)];
        let mut x = randt(&mut rng, &shape, 1.0);
        // keep inputs off the kink so no probe straddles it
        for v in x.data.iter_mut() {
            if v.abs() < 0.05 {
                *v = 0.05 * if *v < 0.0 { -1.0 } else { 1.0 };
            }
        }
        check_op("relu", seed, &[x], |t, v| t.relu(v[0]), 1e-2, 1e-3, 1e-5);
    }
}

#[test]
fn gradcheck_layer_norm() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed ^ 0x50);
        let (r, d) = (1 + rng.below(5), 4 + rng.below(12));
        let inputs = vec![
            randt(&mut rng, &[r, d], 1.0),
            randt(&mut rng, &[d], 0.5),
            randt(&mut rng, &[d], 0.5),
        ];
        check_op(
            "layer_norm",
            seed,
            &inputs,
            |t, v| t.layer_norm(v[0], v[1], v[2]),
            1e-2,
            2e-2,
            2e-3,
        );
    }
}

#[test]
fn gradcheck_causal_attention() {
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed ^ 0x60);
        let dims = AttnDims {
            b: 1 + rng.below(2),
            n: 2 + rng.below(4),
            heads: [1, 2][rng.below(2)],
            d: 8,
        };
        let m = dims.b * dims.n;
        let inputs = vec![
            randt(&mut rng, &[m, dims.d], 1.0),
            randt(&mut rng, &[m, dims.d], 1.0),
            randt(&mut rng, &[m, dims.d], 1.0),
        ];
        check_op(
            "causal_attention",
            seed,
            &inputs,
            move |t, v| t.causal_attention(v[0], v[1], v[2], dims),
            1e-2,
            2e-2,
            2e-3,
        );
    }
}

#[test]
fn gradcheck_embed() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed ^ 0x70);
        let (vocab, d) = (4 + rng.below(8), 2 + rng.below(6));
        let (b, n) = (1 + rng.below(2), 2 + rng.below(4));
        let table = randt(&mut rng, &[vocab, d], 1.0);
        let tok = IntTensor::new(
            vec![b, n],
            (0..b * n).map(|_| rng.below(vocab) as i32).collect(),
        );
        check_op(
            "embed",
            seed,
            &[table],
            move |t, v| t.embed(v[0], &tok),
            1e-2,
            1e-3,
            1e-5,
        );
    }
}

#[test]
fn gradcheck_cross_entropy() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed ^ 0x80);
        let (rows, vocab) = (2 + rng.below(6), 4 + rng.below(12));
        let logits = randt(&mut rng, &[rows, vocab], 2.0);
        let targets = IntTensor::new(
            vec![rows],
            (0..rows).map(|_| rng.below(vocab) as i32).collect(),
        );
        check_op(
            "cross_entropy",
            seed,
            &[logits],
            move |t, v| t.cross_entropy(v[0], &targets),
            1e-2,
            2e-2,
            1e-4,
        );
    }
}

/// End-to-end: a full subspace-mode pipeline stage (boundary
/// reconstruction, transformer block with attention+ReLU MLP, final LN,
/// head, cross-entropy) checked as one composed graph — catches wiring
/// bugs no per-op check can.
#[test]
fn gradcheck_full_stage_composition() {
    let h = Hyper {
        d: 8,
        d_ff: 16,
        heads: 2,
        layers: 2,
        stages: 2,
        n: 4,
        vocab: 10,
        k: 3,
        b: 2,
        blocks_per_stage: 1,
        ratio: 8.0 / 3.0,
        param_count: 0,
    };
    let m = h.b * h.n;
    let (eps, rtol, atol) = (1e-2f32, 4e-2, 5e-4);
    for seed in 0..3u64 {
        let mut rng = Rng::new(seed ^ 0x90);
        let global = GlobalState::from_hyper(&h, &mut rng);
        let last = h.stages - 1;
        let st = StageState::from_schema(
            h.stage_schema(last),
            "last",
            last,
            Mode::Subspace,
            &global,
            &mut rng,
        )
        .unwrap();
        let tok = IntTensor::new(
            vec![h.b, h.n],
            (0..m).map(|_| rng.below(h.vocab) as i32).collect(),
        );
        let tgt = IntTensor::new(
            vec![h.b, h.n],
            (0..m).map(|_| rng.below(h.vocab) as i32).collect(),
        );
        let pe = sinusoidal_pe(h.n, h.d);
        let e = high_rank_e(&h, Mode::Subspace, &pe, &global.t_fixed, &tok);
        let xc = randt(&mut rng, &[m, h.k], 0.5);

        let loss_of = |params: &[Tensor], xc: &Tensor| -> f64 {
            let b = build_stage(
                &h,
                Mode::Subspace,
                last,
                params,
                StageIo {
                    u: &global.u,
                    e: &e,
                    tok: &tok,
                    input: Some(xc),
                    targets: Some(&tgt),
                },
            );
            b.tape.value(b.output).item() as f64
        };
        // analytic gradients of the composed stage
        let built = {
            let mut b = build_stage(
                &h,
                Mode::Subspace,
                last,
                &st.params,
                StageIo {
                    u: &global.u,
                    e: &e,
                    tok: &tok,
                    input: Some(&xc),
                    targets: Some(&tgt),
                },
            );
            b.tape.backward(b.output);
            b
        };
        let (mut checked, mut skipped) = (0usize, 0usize);
        // boundary-input gradient: every coefficient
        let gin = built.tape.grad(built.input.unwrap()).unwrap();
        for idx in 0..xc.numel() {
            let probe = |delta: f32| -> f64 {
                let mut p = xc.clone();
                p.data[idx] += delta;
                loss_of(&st.params, &p)
            };
            let Some(fd) = two_scale_fd(&probe, eps, atol) else {
                skipped += 1;
                continue;
            };
            checked += 1;
            let an = gin.data[idx] as f64;
            assert!(
                close(fd, an, rtol, atol),
                "seed {seed} xc[{idx}]: fd {fd:.5e} vs tape {an:.5e}"
            );
        }
        // a sample of elements from every parameter
        let mut irng = Rng::new(seed ^ 0xA0);
        for (pi, p0) in st.params.iter().enumerate() {
            let g = built.tape.grad(built.params[pi]).unwrap();
            for _ in 0..6 {
                let idx = irng.below(p0.numel());
                let probe = |delta: f32| -> f64 {
                    let mut plus = st.params.to_vec();
                    plus[pi].data[idx] += delta;
                    loss_of(&plus, &xc)
                };
                let Some(fd) = two_scale_fd(&probe, eps, atol) else {
                    skipped += 1;
                    continue;
                };
                checked += 1;
                let an = g.data[idx] as f64;
                assert!(
                    close(fd, an, rtol, atol),
                    "seed {seed} param {pi} elem {idx}: fd {fd:.5e} vs \
                     tape {an:.5e}"
                );
            }
        }
        assert!(
            skipped * 2 <= checked,
            "seed {seed}: {skipped} skipped vs {checked} checked"
        );
    }
}
