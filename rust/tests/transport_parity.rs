//! The distributed transport's parity contract (DESIGN.md §11): a
//! channel- or TCP-distributed run reproduces the single-process native
//! backend's loss curve **bitwise**, the wire carries exactly the bytes
//! `compress::wire_bytes` prices, and a vanished or misconfigured peer
//! surfaces as a graceful churn-style error. This suite is
//! artifact-free and runs on every CI matrix leg (all pool widths — the
//! transport must be immune to the thread-count environment).

use protomodels::compress::{wire_bytes, Mode};
use protomodels::coordinator::PipelineConfig;
use protomodels::data::CorpusKind;
use protomodels::manifest::Hyper;
use protomodels::netsim::{LinkSpec, Topology};
use protomodels::nn::{NativePipeline, Optim};
use protomodels::rng::Rng;
use protomodels::sim::Schedule;
use protomodels::transport::{
    channel_pair, run_local, FaultSchedule, FaultTransport, FrameKind,
    Transport, TransportKind, WireFrame, WorkerSpec,
};

fn spec(mode: Mode, steps: usize, stages: usize) -> WorkerSpec {
    let mut h = Hyper::tiny_native();
    h.stages = stages;
    h.layers = h.blocks_per_stage * stages;
    WorkerSpec {
        h,
        cfg: PipelineConfig {
            mode,
            microbatches: 2,
            grassmann_interval: 0,
            lr: 1e-2,
            warmup_steps: 3,
            total_steps: steps,
            seed: 7,
            ..Default::default()
        },
        optim: Optim::AdamW,
        steps,
        corpus_kind: CorpusKind::Wiki,
        corpus_tokens: 60_000,
    }
}

/// Reference loss curve from the single-process backend.
fn single_process(s: &WorkerSpec) -> Vec<f64> {
    let h = s.h.clone();
    let mut rng = Rng::new(s.cfg.seed);
    let topo =
        Topology::uniform(h.stages, LinkSpec::internet_80m(), &mut rng);
    let corpus = s.corpus();
    let mut pipe =
        NativePipeline::new(h.clone(), topo, s.cfg.clone(), s.optim)
            .expect("native pipeline");
    (0..s.steps)
        .map(|_| {
            pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))
                .expect("train step")
                .loss
        })
        .collect()
}

fn assert_bitwise(label: &str, reference: &[f64], got: &[f64]) {
    assert_eq!(reference.len(), got.len(), "{label}: curve length");
    for (i, (a, b)) in reference.iter().zip(got).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{label}: loss diverged at step {} ({a} vs {b})",
            i + 1
        );
    }
}

#[test]
fn channel_run_matches_single_process_bitwise_with_grassmann() {
    // Grassmann on: the U-basis relay + per-worker re-projection path
    // must reproduce the in-process update exactly
    let mut s = spec(Mode::Subspace, 24, 4);
    s.cfg.grassmann_interval = 8;
    let reference = single_process(&s);
    let rep = run_local(&s, TransportKind::Channel).expect("channel run");
    assert_bitwise("channel/subspace+grassmann", &reference, &rep.losses);
    assert!(rep.losses.iter().all(|l| l.is_finite() && *l > 0.0));
}

#[test]
fn every_codec_is_transport_parity_clean() {
    // lossy codecs too: the wire moves the codec's exact bytes, so even
    // a lossy boundary is *deterministically* lossy — bitwise parity
    // holds for every mode, including PowerLR's sketch-RNG path
    for mode in [
        Mode::Raw,
        Mode::TopK,
        Mode::Quant,
        Mode::PowerLR,
        Mode::NoFixed,
        Mode::RawBf16,
        Mode::SubspaceBf16,
    ] {
        let s = spec(mode, 6, 4);
        let reference = single_process(&s);
        let rep = run_local(&s, TransportKind::Channel)
            .unwrap_or_else(|e| panic!("{mode:?} channel run: {e}"));
        assert_bitwise(mode.as_str(), &reference, &rep.losses);
    }
}

#[test]
fn tcp_loopback_matches_single_process_bitwise() {
    let s = spec(Mode::Subspace, 8, 2);
    let reference = single_process(&s);
    let rep = run_local(&s, TransportKind::Tcp).expect("tcp run");
    assert_bitwise("tcp/subspace", &reference, &rep.losses);
}

#[test]
fn one_f_one_b_schedule_same_losses_more_overlap() {
    // the wave order changes buffering, never arithmetic
    let gpipe = spec(Mode::Subspace, 8, 4);
    let reference = single_process(&gpipe);
    let mut s = gpipe;
    s.cfg.schedule = Schedule::OneFOneB;
    let rep = run_local(&s, TransportKind::Channel).expect("1f1b run");
    assert_bitwise("channel/1f1b", &reference, &rep.losses);
}

#[test]
fn wire_payloads_match_accounting_and_subspace_ratio() {
    let sub = spec(Mode::Subspace, 4, 4);
    let raw = spec(Mode::Raw, 4, 4);
    let rep_sub = run_local(&sub, TransportKind::Channel).expect("sub");
    let rep_raw = run_local(&raw, TransportKind::Channel).expect("raw");
    let h = &sub.h;
    assert_eq!(
        rep_sub.frame_payload_bytes,
        wire_bytes(Mode::Subspace, h.b, h.n, h.d, h.k, h.ratio)
    );
    assert_eq!(
        rep_raw.frame_payload_bytes,
        wire_bytes(Mode::Raw, h.b, h.n, h.d, h.k, h.ratio)
    );
    let ratio =
        rep_raw.frame_payload_bytes as f64 / rep_sub.frame_payload_bytes as f64;
    assert!(ratio >= 10.0, "subspace only {ratio:.1}x smaller");
    // boundary totals: frames × payload, nothing hidden
    let boundary_frames =
        (2 * (h.stages - 1) * sub.cfg.microbatches * sub.steps) as u64;
    assert_eq!(
        rep_sub.boundary_payload_bytes,
        boundary_frames * rep_sub.frame_payload_bytes as u64
    );
}

#[test]
fn mismatched_configs_refuse_to_train() {
    // two workers launched with different seeds must reject each other
    // at the handshake, not train a silently-divergent model
    let a = spec(Mode::Subspace, 4, 2);
    let mut b = a.clone();
    b.cfg.seed ^= 0xBAD;
    let (e0, e1) = channel_pair();
    let (ra, rb) = std::thread::scope(|scope| {
        let ha =
            scope.spawn(|| dist_stage(&a, 0, None, Some(Box::new(e0))));
        let hb =
            scope.spawn(|| dist_stage(&b, 1, Some(Box::new(e1)), None));
        (ha.join().unwrap(), hb.join().unwrap())
    });
    for (name, r) in [("stage0", ra), ("stage1", rb)] {
        let err = r.unwrap_err().to_string();
        assert!(err.contains("digest"), "{name}: {err}");
    }
}

#[test]
fn departed_peer_surfaces_as_graceful_churn_error() {
    // a peer that handshakes and then vanishes mid-step must produce a
    // descriptive departure error (the swarm-leave mirror), not a hang
    let s = spec(Mode::Subspace, 4, 2);
    let digest = s.digest();
    let (stage0_end, mut peer_end) = channel_pair();
    let worker = std::thread::scope(|scope| {
        let w = scope
            .spawn(|| dist_stage(&s, 0, None, Some(Box::new(stage0_end))));
        let p = scope.spawn(move || {
            // act like a healthy stage 1 through the handshake…
            peer_end
                .send(&WireFrame::control(FrameKind::Hello, 0, digest))
                .unwrap();
            let hello = peer_end.recv().unwrap();
            assert_eq!(hello.kind, FrameKind::Hello);
            // …drain the step's forward frames, then leave the swarm
            // (draining makes the failure land on stage 0's backward
            // recv, deterministically, rather than racing its sends)
            for mb in 0..2u32 {
                let fwd = peer_end.recv().unwrap();
                assert_eq!(fwd.kind, FrameKind::Fwd);
                assert_eq!(fwd.microbatch, mb);
            }
            drop(peer_end);
        });
        p.join().unwrap();
        w.join().unwrap()
    });
    let err = worker.unwrap_err().to_string();
    assert!(err.contains("departed"), "{err}");
    assert!(err.contains("stage 0"), "should name the stage: {err}");
}

#[test]
fn transparent_fault_wrapper_is_bitwise_invisible() {
    // the chaos harness's FaultTransport under an empty schedule must be
    // a perfect pass-through: a training run with both ends of the chain
    // link wrapped reproduces the single-process curve bitwise
    let s = spec(Mode::Subspace, 6, 2);
    let reference = single_process(&s);
    let (e0, e1) = channel_pair();
    let wrap = |end| {
        Box::new(FaultTransport::new(
            Box::new(end),
            FaultSchedule::transparent(),
        )) as Box<dyn Transport>
    };
    let (r0, r1) = std::thread::scope(|scope| {
        let h0 = scope.spawn(|| dist_stage(&s, 0, None, Some(wrap(e0))));
        let h1 = scope.spawn(|| dist_stage(&s, 1, Some(wrap(e1)), None));
        (h0.join().unwrap(), h1.join().unwrap())
    });
    let rep = r0.expect("stage 0 under transparent faults");
    r1.expect("stage 1 under transparent faults");
    assert_bitwise("channel/transparent-fault", &reference, &rep.losses);
}

#[test]
fn transparent_fault_wrapper_counts_passed_frames_only() {
    // frame-level leg: every frame comes back byte-identical and lands
    // in the `passed` counter — no other counter moves without a fault
    let (mut tx, rx) = channel_pair();
    let sched = FaultSchedule::transparent();
    assert!(sched.is_transparent());
    let mut ft = FaultTransport::new(Box::new(rx), sched);
    let frames = [
        WireFrame::control(FrameKind::Hello, 0, vec![1, 2, 3]),
        WireFrame::boundary(FrameKind::Fwd, Mode::Subspace, 4, 2, vec![9u8; 64]),
        WireFrame::control(FrameKind::Heartbeat, 5, vec![0u8; 16]),
        WireFrame::control(FrameKind::Checkpoint, 6, vec![7u8; 40]),
        WireFrame::control(FrameKind::StepEnd, 6, vec![]),
    ];
    for f in &frames {
        tx.send(f).expect("send");
        let got = ft.recv().expect("recv through transparent wrapper");
        assert_eq!(
            got.to_bytes(),
            f.to_bytes(),
            "frame must cross the wrapper byte-identically"
        );
    }
    let stats = ft.stats();
    assert_eq!(stats.passed, frames.len() as u64);
    assert_eq!(
        (stats.dropped, stats.delayed, stats.truncated, stats.severed),
        (0, 0, 0, 0),
        "no fault counter may move under the empty schedule"
    );
}

/// Thin alias so the tests read as "drive one stage" (the public
/// `serve_stage` adds TCP plumbing we bypass here).
fn dist_stage(
    s: &WorkerSpec,
    stage: usize,
    left: Option<Box<dyn Transport>>,
    right: Option<Box<dyn Transport>>,
) -> anyhow::Result<protomodels::transport::WorkerReport> {
    protomodels::transport::dist::run_stage(s, stage, left, right)
}

// ---------------------------------------------------------------------------
// the data-parallel axis (DESIGN.md §14): R×P grids vs the in-process
// replica path
// ---------------------------------------------------------------------------

/// A validated R×P grid spec on the tiny preset.
fn grid_spec(
    replicas: usize,
    stages: usize,
    dp_mode: Mode,
    reduce: protomodels::transport::Reduce,
    steps: usize,
) -> protomodels::transport::TrainSpec {
    let mut t =
        protomodels::transport::TrainSpec::from_worker(spec(
            Mode::Subspace,
            steps,
            stages,
        ));
    t.replicas = replicas;
    t.dp_mode = dp_mode;
    t.reduce = reduce;
    t.validate().expect("grid spec validates");
    t
}

#[test]
fn ring_grid_matrix_matches_the_replica_reference_bitwise() {
    // the acceptance matrix: R ∈ {1,2,3} × every dp codec, over
    // channel — a ring grid's per-step loss (mean over replicas) must
    // reproduce the single-process replica path BITWISE, because the
    // wire ring performs the identical codec arithmetic in the
    // identical order (lossy codecs are deterministically lossy)
    use protomodels::transport::{launch, reference_dp_losses, Reduce};
    for replicas in [1usize, 2, 3] {
        for dp_mode in [
            Mode::Raw,
            Mode::RawBf16,
            Mode::Quant,
            Mode::TopK,
            Mode::Subspace,
            Mode::SubspaceBf16,
        ] {
            let reduce =
                if replicas == 1 { Reduce::None } else { Reduce::Ring };
            let t = grid_spec(replicas, 2, dp_mode, reduce, 3);
            let reference = reference_dp_losses(&t)
                .unwrap_or_else(|e| panic!("reference R={replicas}: {e}"));
            let rep = launch(&t.topology(TransportKind::Channel), &t)
                .unwrap_or_else(|e| {
                    panic!("R={replicas} {dp_mode:?} grid: {e}")
                });
            assert_bitwise(
                &format!("ring R={replicas} {dp_mode:?}"),
                &reference,
                &rep.losses,
            );
            assert_eq!(rep.survivors, replicas);
            if replicas > 1 {
                assert!(rep.dp_payload_bytes > 0, "dp wire was silent");
            } else {
                assert_eq!(rep.dp_payload_bytes, 0);
            }
            // R = 1 is exactly the classic single-chain run
            if replicas == 1 && dp_mode == Mode::Raw {
                let sp = single_process(&t.worker);
                assert_bitwise("R=1 vs single-process", &sp, &rep.losses);
            }
        }
    }
}

#[test]
fn tcp_ring_grid_matches_the_replica_reference_bitwise() {
    // same contract over real loopback sockets (both dp mesh and chains)
    use protomodels::transport::{launch, reference_dp_losses, Reduce};
    for dp_mode in [Mode::Raw, Mode::Subspace] {
        let t = grid_spec(2, 2, dp_mode, Reduce::Ring, 3);
        let reference = reference_dp_losses(&t).expect("reference");
        let rep = launch(&t.topology(TransportKind::Tcp), &t)
            .unwrap_or_else(|e| panic!("tcp grid {dp_mode:?}: {e}"));
        assert_bitwise(
            &format!("tcp ring {dp_mode:?}"),
            &reference,
            &rep.losses,
        );
    }
}

#[test]
fn gossip_grid_without_churn_matches_the_reference_bitwise() {
    // kill-free gossip is ALSO deterministic: the pair schedule is
    // seeded, both pair members average the identical post-codec
    // values, so the grid matches the in-process gossip emulation
    // bitwise (the stronger envelope contract lives in chaos.rs)
    use protomodels::transport::{launch, reference_dp_losses, Reduce};
    for (replicas, dp_mode) in
        [(2usize, Mode::Raw), (3, Mode::Quant), (3, Mode::Raw)]
    {
        let t = grid_spec(
            replicas,
            2,
            dp_mode,
            Reduce::Gossip { degree: 1 },
            4,
        );
        let reference = reference_dp_losses(&t).expect("reference");
        let rep = launch(&t.topology(TransportKind::Channel), &t)
            .unwrap_or_else(|e| {
                panic!("gossip R={replicas} {dp_mode:?}: {e}")
            });
        assert_bitwise(
            &format!("gossip R={replicas} {dp_mode:?}"),
            &reference,
            &rep.losses,
        );
        assert_eq!(rep.survivors, replicas);
    }
}

#[test]
fn ring_dp_payload_bytes_match_the_memory_pricing() {
    // every gradient frame's payload is priced by dp_wire_bytes; the
    // run's dp byte total must therefore equal the memory model's
    // ring pricing (minus the frame headers it includes) exactly
    use protomodels::memory::dp_ring_step_wire_bytes;
    use protomodels::transport::{launch, Reduce, HEADER_LEN};
    let t = grid_spec(2, 2, Mode::Subspace, Reduce::Ring, 2);
    let w = &t.worker;
    // measure each stage's gradient element count in process
    let h = w.h.clone();
    let mut rng = Rng::new(w.cfg.seed);
    let topo =
        Topology::uniform(h.stages, LinkSpec::internet_80m(), &mut rng);
    let mut pipe =
        NativePipeline::new(h.clone(), topo, w.cfg.clone(), w.optim)
            .expect("pipe");
    let corpus = w.corpus();
    let pending = pipe
        .forward_backward(|r| corpus.train_batch(h.b, h.n, r))
        .expect("fb");
    let elems: Vec<usize> = pending
        .grad_acc
        .iter()
        .map(|g| g.iter().map(|t| t.numel()).sum())
        .collect();
    let r = t.replicas;
    let per_step: u64 = elems
        .iter()
        .map(|&e| {
            let priced = dp_ring_step_wire_bytes(
                e, r, t.dp_mode, h.d, h.k, h.ratio,
            ) as u64;
            // the pricing includes one header per frame; the report
            // counts codec payload only
            priced - (2 * (r - 1) * r * HEADER_LEN) as u64
        })
        .sum();
    // every replica worker counts its own sends: R× the per-ring total
    // is already folded in (each of the R workers sends 2(R−1) frames,
    // which together cover each chunk once per phase)
    let rep = launch(&t.topology(TransportKind::Channel), &t)
        .expect("grid");
    assert_eq!(
        rep.dp_payload_bytes,
        per_step * w.steps as u64,
        "measured dp payload diverged from memory::dp_ring_step_wire_bytes"
    );
}
