//! The chaos harness's flagship contract (DESIGN.md §12): a distributed
//! run that loses and replaces workers mid-flight must *rejoin the
//! no-churn loss curve* — bitwise under the raw checkpoint codec — with
//! every recovery byte priced by `memory::checkpoint_payload_bytes`,
//! and the discrete-event swarm simulator must predict the envelope for
//! the *same* churn timeline the elastic runtime executed. Faults are
//! injected from seeded deterministic schedules, so every failure in
//! this suite reproduces exactly.

use protomodels::compress::{CkptCodec, Mode};
use protomodels::coordinator::PipelineConfig;
use protomodels::data::CorpusKind;
use protomodels::manifest::Hyper;
use protomodels::memory::{checkpoint_payload_bytes, heartbeat_payload_bytes};
use protomodels::nn::Optim;
use protomodels::sim::{simulate_swarm, ChurnTimeline, SwarmSpec};
use protomodels::transport::{
    run_elastic, run_local, ElasticSpec, FaultFamily, FaultPlan,
    FaultSchedule, LinkSide, TransportKind, WorkerSpec,
};

fn spec(mode: Mode, steps: usize, stages: usize) -> WorkerSpec {
    let mut h = Hyper::tiny_native();
    h.stages = stages;
    h.layers = h.blocks_per_stage * stages;
    WorkerSpec {
        h,
        cfg: PipelineConfig {
            mode,
            microbatches: 2,
            grassmann_interval: 0,
            lr: 1e-2,
            warmup_steps: 3,
            total_steps: steps,
            seed: 11,
            ..Default::default()
        },
        optim: Optim::AdamW,
        steps,
        corpus_kind: CorpusKind::Wiki,
        corpus_tokens: 60_000,
    }
}

/// The no-churn reference curve, from the already-proven distributed
/// runtime (itself bitwise-equal to the single-process backend — see
/// `transport_parity.rs`).
fn clean_curve(s: &WorkerSpec) -> Vec<f64> {
    run_local(s, TransportKind::Channel)
        .expect("clean distributed run")
        .losses
}

fn assert_bitwise(label: &str, reference: &[f64], got: &[f64]) {
    assert_eq!(reference.len(), got.len(), "{label}: curve length");
    for (i, (a, b)) in reference.iter().zip(got).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{label}: loss diverged at step {} ({a} vs {b})",
            i + 1
        );
    }
}

/// Total checkpoint payload bytes one complete boundary costs, summed
/// over every stage — the memory.rs cost model the wire is held to.
fn boundary_cost(s: &WorkerSpec, codec: CkptCodec) -> u64 {
    let p = s.h.stages;
    (0..p)
        .map(|st| {
            checkpoint_payload_bytes(
                &s.h,
                st,
                s.cfg.mode,
                codec,
                st == p - 1 && s.cfg.compressed(),
            ) as u64
        })
        .sum()
}

#[test]
fn killed_worker_recovers_and_rejoins_the_clean_curve_bitwise() {
    // the flagship: kill worker 1 at step 15 of an 18-step run with a
    // checkpoint every 6 steps. The supervisor must detect the death,
    // hand the stage to a spare, resync everyone from boundary 12, and
    // finish — and under the raw checkpoint codec the final curve is
    // BITWISE the no-churn curve (the paper-level claim: churn costs
    // recomputation, never training fidelity)
    let s = spec(Mode::Subspace, 18, 3);
    let reference = clean_curve(&s);
    let mut es = ElasticSpec::new(s.clone());
    es.ckpt_every = 6;
    es.ckpt_codec = CkptCodec::Raw;
    es.chaos = ChurnTimeline::parse("kill:1@15").expect("timeline");
    let rep = run_elastic(&es, TransportKind::Channel).expect("elastic run");

    assert_bitwise("chaos/kill+spare", &reference, &rep.losses);
    assert_eq!(rep.epochs, 2, "one failed epoch, one clean epoch");
    assert_eq!(rep.recoveries, 1);
    assert_eq!(
        rep.resume_steps,
        vec![12],
        "must resume from the newest complete boundary before the kill"
    );
    assert_eq!(rep.spares_used, 1, "no rejoin scripted: a spare steps in");

    // ---- recovery wire bytes against the memory.rs cost model ----
    // epoch 0 ships boundaries 6 and 12 from each stage before dying at
    // step 15; the recovery epoch (12..18) ships boundary 18: three
    // complete boundaries, never a partial one
    let p = s.h.stages as u64;
    assert_eq!(rep.ckpt_frames % p, 0, "no partial checkpoint boundary");
    assert_eq!(rep.ckpt_frames / p, 3, "boundaries 6, 12, 18");
    assert_eq!(
        rep.ckpt_bytes,
        (rep.ckpt_frames / p) * boundary_cost(&s, CkptCodec::Raw),
        "checkpoint wire bytes must match memory::checkpoint_payload_bytes"
    );
    assert!(rep.heartbeat_frames > 0, "liveness beacons must have flowed");
    assert_eq!(
        rep.heartbeat_bytes,
        rep.heartbeat_frames * heartbeat_payload_bytes() as u64,
        "heartbeat wire bytes must match memory::heartbeat_payload_bytes"
    );
}

#[test]
fn scripted_rejoin_consumes_no_spare() {
    // kill:1@3,join:1@4 — the same worker restarts, so the recovery must
    // succeed with zero spares configured and still rejoin bitwise
    let s = spec(Mode::Subspace, 8, 2);
    let reference = clean_curve(&s);
    let mut es = ElasticSpec::new(s);
    es.ckpt_every = 2;
    es.spares = 0;
    es.chaos = ChurnTimeline::parse("kill:1@3,join:1@4").expect("timeline");
    let rep = run_elastic(&es, TransportKind::Channel).expect("elastic run");
    assert_bitwise("chaos/rejoin", &reference, &rep.losses);
    assert_eq!(rep.recoveries, 1);
    assert_eq!(rep.spares_used, 0, "a scripted rejoin is not a spare");
    assert_eq!(rep.resume_steps, vec![2]);
}

#[test]
fn spare_exhaustion_is_a_descriptive_error_not_a_hang() {
    let s = spec(Mode::Subspace, 6, 2);
    let mut es = ElasticSpec::new(s);
    es.ckpt_every = 3;
    es.spares = 0;
    es.chaos = ChurnTimeline::parse("kill:1@4").expect("timeline");
    let err = run_elastic(&es, TransportKind::Channel)
        .expect_err("a permanent leave with no spare cannot complete")
        .to_string();
    assert!(err.contains("no spare remains"), "{err}");
    assert!(err.contains("unrecoverable churn"), "{err}");
}

/// A seeded fault plan targeting stage 1's left chain link during the
/// first epoch only (recovery epochs run on clean links, mirroring a
/// transient network event).
fn fault_plan(seed: u64, horizon: u64, family: FaultFamily) -> FaultPlan {
    FaultPlan {
        target_epoch: 0,
        entries: vec![(
            1,
            LinkSide::Left,
            FaultSchedule::seeded(seed, horizon, family),
        )],
    }
}

#[test]
fn drop_heavy_link_faults_trigger_recovery_and_bitwise_rejoin() {
    // dropped frames desynchronize the stream (wrong microbatch / kind /
    // missing hello), which must surface as a protocol error, tear the
    // epoch down, and recover — never train on misordered tensors
    let s = spec(Mode::Subspace, 8, 2);
    let reference = clean_curve(&s);
    let mut es = ElasticSpec::new(s);
    es.ckpt_every = 4;
    es.stale_ms = 400; // bound the post-drop silence, keep the test fast
    es.faults = fault_plan(33, 32, FaultFamily::DropHeavy);
    let rep = run_elastic(&es, TransportKind::Channel).expect("elastic run");
    assert_bitwise("chaos/drop-heavy", &reference, &rep.losses);
    assert_eq!(rep.recoveries, 1, "the drop-scarred epoch must fail once");
    assert_eq!(rep.spares_used, 0, "a link fault is not a worker death");
}

#[test]
fn severed_link_triggers_recovery_and_bitwise_rejoin() {
    let s = spec(Mode::Subspace, 8, 2);
    let reference = clean_curve(&s);
    let mut es = ElasticSpec::new(s);
    es.ckpt_every = 4;
    es.stale_ms = 400;
    // horizon 16 puts the single sever inside epoch 0's receive range
    es.faults = fault_plan(7, 16, FaultFamily::Sever);
    let rep = run_elastic(&es, TransportKind::Channel).expect("elastic run");
    assert_bitwise("chaos/sever", &reference, &rep.losses);
    assert_eq!(rep.recoveries, 1);
    // whatever boundary the cut landed after, the resume point is one
    // the checkpoint cadence produced
    assert_eq!(rep.resume_steps.len(), 1);
    assert_eq!(rep.resume_steps[0] % 4, 0);
}

#[test]
fn small_delays_are_absorbed_without_any_recovery() {
    // 1–5 ms holds sit far under the stale timeout: the liveness layer
    // must wait them out, deliver every frame intact, and finish in one
    // epoch with the exact clean curve — delay is not failure
    let s = spec(Mode::Subspace, 6, 2);
    let reference = clean_curve(&s);
    let mut es = ElasticSpec::new(s);
    es.ckpt_every = 3;
    es.faults = fault_plan(91, 24, FaultFamily::DelayHeavy);
    let rep = run_elastic(&es, TransportKind::Channel).expect("elastic run");
    assert_bitwise("chaos/delay-heavy", &reference, &rep.losses);
    assert_eq!(rep.recoveries, 0, "delays under the deadline never kill");
    assert_eq!(rep.epochs, 1);
    assert_eq!(rep.spares_used, 0);
}

#[test]
fn swarm_simulator_prices_the_same_churn_timeline() {
    // the envelope leg: the discrete-event simulator consumes the SAME
    // step-indexed timeline `scripted_rejoin_consumes_no_spare` executes
    // on the real runtime, lowered onto the simulator's own measured
    // clock, and must predict the churn's cost — a membership dip and a
    // priced resync. The loss-curve side of the envelope is exact: the
    // raw-codec chaos runs above rejoin the clean curve bitwise, which
    // lies inside any envelope the simulator predicts for this timeline.
    let timeline =
        ChurnTimeline::parse("kill:1@3,join:1@4").expect("timeline");
    timeline.validate(4, 8).expect("shape-checked script");
    assert_eq!(timeline.leaves(), 1);
    assert_eq!(timeline.kills_at(3), vec![1]);
    assert!(!timeline.is_empty());

    let mut sim = SwarmSpec::uniform(Hyper::tiny_native(), 4, 80e6);
    sim.steps = 8;
    let clean = simulate_swarm(&sim).expect("clean sim");
    assert_eq!(clean.leaves, 0);

    // lower step indices onto the simulator's measured step time, so
    // "during step 3" lands during step 3 of the simulated run
    let step_s = clean.total / clean.steps as f64;
    sim.churn = timeline.to_scripted(step_s);
    let churned = simulate_swarm(&sim).expect("churned sim");

    assert_eq!(churned.leaves, 1, "the scripted kill must land");
    assert_eq!(churned.rejoins, 1, "the scripted restart must land");
    assert!(
        churned.sync_seconds > 0.0,
        "a rejoin pays a priced state resync"
    );
    assert!(
        churned.min_active >= 3,
        "exactly one member may be down at the trough: {}",
        churned.min_active
    );
    assert_eq!(churned.steps, 8);
    assert!(churned.total.is_finite() && churned.total > 0.0);
}

#[test]
fn coeff_checkpoint_codec_prices_smaller_and_still_converges() {
    // the compressed checkpoint codec ships constrained parameters as
    // k-dim coefficient rows (priced by dp_wire_bytes): a boundary must
    // cost strictly less than raw, the wire must match the model, and a
    // recovery through a coeff checkpoint must still complete with a
    // finite curve (raw's bitwise guarantee is relaxed to within
    // float-rounding of the clean curve)
    let s = spec(Mode::Subspace, 8, 2);
    let reference = clean_curve(&s);
    let raw_cost = boundary_cost(&s, CkptCodec::Raw);
    let coeff_cost = boundary_cost(&s, CkptCodec::Coeff);
    assert!(
        coeff_cost < raw_cost,
        "coeff boundary ({coeff_cost} B) must undercut raw ({raw_cost} B)"
    );

    let mut es = ElasticSpec::new(s.clone());
    es.ckpt_every = 4;
    es.ckpt_codec = CkptCodec::Coeff;
    es.chaos = ChurnTimeline::parse("kill:1@6").expect("timeline");
    let rep = run_elastic(&es, TransportKind::Channel).expect("elastic run");
    assert_eq!(rep.recoveries, 1);
    let p = s.h.stages as u64;
    assert_eq!(rep.ckpt_frames % p, 0);
    assert_eq!(rep.ckpt_bytes, (rep.ckpt_frames / p) * coeff_cost);
    assert_eq!(rep.losses.len(), reference.len());
    for (i, (a, b)) in reference.iter().zip(&rep.losses).enumerate() {
        assert!(b.is_finite(), "step {}: non-finite loss", i + 1);
        let tol = 1e-3 * a.abs().max(1.0);
        assert!(
            (a - b).abs() <= tol,
            "step {}: {b} strayed past float-rounding of {a}",
            i + 1
        );
    }
}

// ---------------------------------------------------------------------------
// gossip grids under churn (DESIGN.md §14): convergence envelope, not
// bitwise parity
// ---------------------------------------------------------------------------

#[test]
fn gossip_grid_survives_a_seeded_replica_kill_inside_the_envelope() {
    // a 3×2 gossip grid loses one replica mid-run (scripted, seeded);
    // the survivors must (a) finish every step, (b) never hang on the
    // dead peer (departed exchanges are skipped, the schedule is over
    // the full replica set so survivor pairings stay consistent), and
    // (c) land inside a convergence envelope around the churn-free
    // grid: same downward trend, final loss within a small relative
    // band — gossip's contract is statistical alignment, not parity
    use protomodels::transport::{launch, Reduce, TrainSpec};
    let steps = 8usize;
    let kill_step = 3u64;
    let mut t = TrainSpec::from_worker(spec(Mode::Subspace, steps, 2));
    t.replicas = 3;
    t.dp_mode = Mode::Raw;
    t.reduce = Reduce::Gossip { degree: 1 };
    t.validate().expect("gossip grid spec");

    let clean = launch(&t.topology(TransportKind::Channel), &t)
        .expect("churn-free gossip grid");
    assert_eq!(clean.survivors, 3);

    let mut topo = t.topology(TransportKind::Channel);
    topo.chaos_kill = Some((1, kill_step));
    let churned = launch(&topo, &t).expect("gossip grid under churn");
    assert_eq!(churned.survivors, 2, "exactly one replica was killed");
    assert_eq!(
        churned.losses.len(),
        steps,
        "survivors must finish every step"
    );
    // a yanked replica dies without reporting: its curve is empty
    assert!(churned.replica_losses[1].is_empty());
    for l in &churned.losses {
        assert!(l.is_finite() && *l > 0.0, "bad loss {l}");
    }
    // each survivor's own curve matches its clean-run curve bitwise
    // through the kill step (the step-3 loss is computed before the
    // failed exchange): divergence starts only once the dead peer's
    // gradients stop arriving
    for r in [0usize, 2] {
        for i in 0..=kill_step as usize {
            assert_eq!(
                clean.replica_losses[r][i].to_bits(),
                churned.replica_losses[r][i].to_bits(),
                "replica {r} step {i} precedes the kill's effect"
            );
        }
    }
    // convergence envelope: both runs still train (first -> last loss
    // strictly decreasing) and the churned final loss stays within 10%
    // of the clean one
    let (c0, c1) = (clean.losses[0], *clean.losses.last().unwrap());
    let k1 = *churned.losses.last().unwrap();
    assert!(c1 < c0, "clean gossip run failed to train ({c0} -> {c1})");
    assert!(
        k1 < churned.losses[0],
        "churned gossip run failed to train"
    );
    assert!(
        (k1 - c1).abs() / c1 < 0.10,
        "churned final loss {k1} escaped the ±10% envelope around {c1}"
    );
}

#[test]
fn gossip_schedule_is_churn_consistent_across_workers() {
    // the gossip schedule must be computable from shared config alone —
    // over the FULL replica set, never the live set — so workers with
    // divergent dead-knowledge still derive the same pairings and a
    // kill can never deadlock the survivors into mismatched partners
    use protomodels::transport::{gossip_pairs, gossip_partner};
    let (seed, replicas) = (11u64, 5usize);
    for step in 0..50u64 {
        let pairs = gossip_pairs(seed, step, replicas);
        for me in 0..replicas {
            let p = gossip_partner(seed, step, replicas, me);
            if let Some(peer) = p {
                assert_ne!(peer, me);
                assert_eq!(
                    gossip_partner(seed, step, replicas, peer),
                    Some(me),
                    "step {step}: pairing must be symmetric"
                );
                assert!(pairs.contains(&(me, peer)) || pairs.contains(&(peer, me)));
            }
        }
        // exactly one replica idles per step at odd R
        let idle = (0..replicas)
            .filter(|&m| gossip_partner(seed, step, replicas, m).is_none())
            .count();
        assert_eq!(idle, replicas % 2);
    }
}

#[test]
fn injected_fault_counts_equal_observed_fault_counters() {
    // the observability contract for the chaos harness (DESIGN.md §15):
    // what a seeded schedule injects is exactly what FaultStats counts,
    // and RunMetrics::absorb_fault mirrors those counts verbatim — a
    // schedule that silently never fires cannot pass as coverage
    use protomodels::obs::counters::RunMetrics;
    use protomodels::transport::{
        channel_pair, FaultTransport, FrameKind, Transport, WireFrame,
    };

    let n = 64u64;
    let sched = FaultSchedule::seeded(0x5EED, n, FaultFamily::DropHeavy);
    let expected_drops =
        sched.events().iter().filter(|e| e.at < n).count() as u64;
    assert!(expected_drops > 0, "seeded schedule never fires in horizon");

    let (mut tx, b) = channel_pair();
    let mut rx = FaultTransport::new(Box::new(b), sched);
    for i in 0..n {
        tx.send(&WireFrame::control(FrameKind::Heartbeat, i, vec![0u8; 16]))
            .expect("send");
    }
    let mut delivered = 0u64;
    while rx
        .recv_timeout(std::time::Duration::from_millis(50))
        .expect("recv")
        .is_some()
    {
        delivered += 1;
    }
    let stats = rx.stats();
    assert_eq!(stats.dropped, expected_drops);
    assert_eq!(stats.passed, n - expected_drops);
    assert_eq!(delivered, stats.passed + stats.delayed);
    assert_eq!(stats.delayed + stats.truncated + stats.severed, 0);

    let mut m = RunMetrics::new();
    m.absorb_fault(&stats);
    assert_eq!(m.counter("fault.dropped"), stats.dropped);
    assert_eq!(m.counter("fault.passed"), stats.passed);
    assert_eq!(m.counter("fault.delayed"), stats.delayed);
    assert_eq!(m.counter("fault.truncated"), stats.truncated);
    assert_eq!(m.counter("fault.severed"), stats.severed);
}
