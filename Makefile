.PHONY: artifacts build test bench bench-full bench-micro bench-check \
        bench-baseline sim-grid churn-sweep clean

# AOT-lower the JAX numerics to HLO text + manifest (needs python/jax).
# The rust tests look for artifacts under rust/artifacts; the CLI default
# is ./artifacts, so emit once and symlink.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts
	ln -sfn rust/artifacts artifacts

build:
	cargo build --release

test:
	cargo test -q

# Perf-trajectory suite: writes BENCH_linalg.json + BENCH_pipeline.json
# at the repo root (artifact-free — linalg kernels + analytic cost model).
bench: build
	./target/release/protomodels bench --json --fast

# Same suite at full measurement windows (slower, tighter numbers).
bench-full: build
	./target/release/protomodels bench --json

# The cargo micro-bench binaries (some need `make artifacts` first).
bench-micro:
	cargo bench

# Regression gate: compare the BENCH_*.json written by `make bench`
# against the committed BENCH_baseline/ ceilings (fails on >25%).
bench-check: bench
	./target/release/protomodels bench --check BENCH_baseline

# Re-anchor the committed ceilings from a fresh --fast run on this
# machine: ceiling = 3x the measured mean, machine-dependent
# (…_threadsN) entries dropped. Review the diff before committing —
# the gate inherits it.
define BASELINE_PY
import json, re
for suite in ("linalg", "pipeline", "nn", "transport"):
    cur = json.load(open("BENCH_%s.json" % suite))
    # drop machine-dependent ..._threadsN entries, but keep ..._threads1
    # (produced on every machine and gated by the committed baseline)
    keep = [r for r in cur["results"]
            if not re.search(r"threads(?!1$)\d+$", r["name"])]
    out = {"suite": suite,
           "note": "wall-time ceilings for bench --check; regenerated "
                   "by `make bench-baseline`",
           "results": [{"name": r["name"],
                        "mean_ns": round(r["mean_ns"] * 3)} for r in keep]}
    json.dump(out, open("BENCH_baseline/%s.json" % suite, "w"))
endef
export BASELINE_PY

bench-baseline: bench
	python3 -c "$$BASELINE_PY"

# Discrete-event swarm simulator grids (artifact-free; DESIGN.md §9).
sim-grid: build
	./target/release/protomodels exp sim-grid --out results

churn-sweep: build
	./target/release/protomodels exp churn-sweep --out results

clean:
	cargo clean
	rm -rf rust/artifacts artifacts results BENCH_linalg.json \
	       BENCH_pipeline.json BENCH_nn.json BENCH_transport.json
