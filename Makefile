.PHONY: artifacts build test bench bench-full bench-micro clean

# AOT-lower the JAX numerics to HLO text + manifest (needs python/jax).
# The rust tests look for artifacts under rust/artifacts; the CLI default
# is ./artifacts, so emit once and symlink.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts
	ln -sfn rust/artifacts artifacts

build:
	cargo build --release

test:
	cargo test -q

# Perf-trajectory suite: writes BENCH_linalg.json + BENCH_pipeline.json
# at the repo root (artifact-free — linalg kernels + analytic cost model).
bench: build
	./target/release/protomodels bench --json --fast

# Same suite at full measurement windows (slower, tighter numbers).
bench-full: build
	./target/release/protomodels bench --json

# The cargo micro-bench binaries (some need `make artifacts` first).
bench-micro:
	cargo bench

clean:
	cargo clean
	rm -rf rust/artifacts artifacts results BENCH_linalg.json BENCH_pipeline.json
