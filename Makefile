.PHONY: artifacts build test bench clean

# AOT-lower the JAX numerics to HLO text + manifest (needs python/jax).
# The rust tests look for artifacts under rust/artifacts; the CLI default
# is ./artifacts, so emit once and symlink.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts
	ln -sfn rust/artifacts artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

clean:
	cargo clean
	rm -rf rust/artifacts artifacts results
